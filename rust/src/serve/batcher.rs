//! Request queue + dynamic batcher + LRU plan cache.
//!
//! The [`Batcher`] coalesces requests that dispatched onto the *same*
//! frontier mapping (same compiled plan) into batches, flushing a queue
//! when it reaches `max_batch` requests or when its oldest request has
//! waited `max_wait` simulated cycles. All bookkeeping is in virtual
//! (simulated-cycle) time and iteration order is `BTreeMap`-stable, so
//! batch composition is deterministic for a given request stream.
//!
//! The [`PlanCache`] keeps up to `cap` compiled [`QuantNet`] plans,
//! keyed by [`QuantPlan::cache_key`](crate::quant::QuantPlan::cache_key)
//! and evicted least-recently-used:
//! a serve run touching a handful of frontier mappings compiles each
//! plan once and replays it for every later batch (hit/miss counts and
//! compile time feed the serve dashboard and `bench_infer`).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::Mapping;
use crate::quant::QuantNet;

use super::dispatch::Sla;

/// One inference request in the closed-loop driver.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Request id; doubles as the synthetic-input sample index.
    pub id: u64,
    /// Arrival time, simulated cycles.
    pub arrival: u64,
    /// The request's SLA (drives dispatch and hit-rate accounting).
    pub sla: Sla,
    /// Frontier index the dispatcher chose for this request.
    pub point: usize,
}

/// A flushed batch: requests sharing one frontier mapping.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Frontier index all member requests dispatched to.
    pub point: usize,
    /// Virtual time the batch left the queue.
    pub flushed_at: u64,
    /// Member requests, in arrival order.
    pub requests: Vec<Request>,
}

/// Dynamic same-mapping batcher (see module docs).
pub struct Batcher {
    max_batch: usize,
    max_wait: u64,
    queues: BTreeMap<usize, Vec<Request>>,
}

impl Batcher {
    /// `max_batch` >= 1 requests per flush; `max_wait` in simulated
    /// cycles (0 flushes every request immediately — unbatched mode).
    pub fn new(max_batch: usize, max_wait: u64) -> Self {
        Batcher { max_batch: max_batch.max(1), max_wait, queues: BTreeMap::new() }
    }

    /// Requests currently queued across all mappings.
    pub fn pending(&self) -> usize {
        self.queues.values().map(Vec::len).sum()
    }

    /// Requests currently queued on `point`'s mapping (the obs layer
    /// classifies a push as batch-open vs batch-join with this).
    pub fn pending_for(&self, point: usize) -> usize {
        self.queues.get(&point).map_or(0, Vec::len)
    }

    /// Enqueue one request; returns the flushed batch if its queue just
    /// reached `max_batch`.
    pub fn push(&mut self, r: Request) -> Option<Batch> {
        let (point, now) = (r.point, r.arrival);
        let q = self.queues.entry(point).or_default();
        q.push(r);
        if q.len() >= self.max_batch {
            return Some(self.flush(point, now));
        }
        None
    }

    /// Earliest flush deadline over all non-empty queues (oldest
    /// member's arrival + `max_wait`).
    pub fn next_deadline(&self) -> Option<u64> {
        // saturating: max_wait = u64::MAX is a legal "never flush on
        // wait" setting and must not wrap into an immediate deadline
        self.queues
            .values()
            .filter_map(|q| q.first().map(|r| r.arrival.saturating_add(self.max_wait)))
            .min()
    }

    /// Flush every queue whose deadline has passed at `now`, oldest
    /// deadline first (ties in `point` order — deterministic).
    pub fn due(&mut self, now: u64) -> Vec<Batch> {
        let mut ripe: Vec<(u64, usize)> = self
            .queues
            .iter()
            .filter_map(|(&point, q)| {
                q.first()
                    .map(|r| (r.arrival.saturating_add(self.max_wait), point))
                    .filter(|&(deadline, _)| deadline <= now)
            })
            .collect();
        ripe.sort_unstable();
        ripe.into_iter().map(|(_, point)| self.flush(point, now)).collect()
    }

    /// Remove up to `k` queued requests, oldest first by (arrival, id)
    /// across all mapping queues — the work-stealing donor side. Each
    /// victim queue keeps its remaining requests in order, so deadlines
    /// stay monotone for what stays behind.
    pub fn steal_oldest(&mut self, k: usize) -> Vec<Request> {
        let mut all: Vec<(u64, u64, usize)> = self
            .queues
            .iter()
            .flat_map(|(&point, q)| q.iter().map(move |r| (r.arrival, r.id, point)))
            .collect();
        all.sort_unstable();
        all.truncate(k);
        let mut stolen = Vec::with_capacity(all.len());
        for (_, id, point) in all {
            if let Some(q) = self.queues.get_mut(&point) {
                if let Some(i) = q.iter().position(|r| r.id == id) {
                    stolen.push(q.remove(i));
                }
                if q.is_empty() {
                    self.queues.remove(&point);
                }
            }
        }
        stolen
    }

    /// Flush everything that remains, in `point` order.
    pub fn drain(&mut self, now: u64) -> Vec<Batch> {
        let points: Vec<usize> = self.queues.keys().copied().collect();
        points.into_iter().map(|p| self.flush(p, now)).collect()
    }

    fn flush(&mut self, point: usize, now: u64) -> Batch {
        let requests = self.queues.remove(&point).unwrap_or_default();
        Batch { point, flushed_at: now, requests }
    }
}

// ---- LRU plan cache ---------------------------------------------------

struct CacheEntry {
    key: u64,
    /// The mapping the plan was compiled for: verified on every hit so
    /// a (astronomically unlikely) 64-bit hash collision can never hand
    /// back the wrong compiled plan — the hash is a fast filter, the
    /// mapping is the identity.
    mapping: Mapping,
    last_used: u64,
    net: QuantNet,
}

/// LRU cache of compiled plans, keyed by
/// [`QuantPlan::cache_key`](crate::quant::QuantPlan::cache_key).
/// Plans own their data outright, so the cache can live as long as the
/// owner likes — e.g. across every call of a
/// [`Session`](crate::api::Session).
pub struct PlanCache {
    cap: usize,
    tick: u64,
    entries: Vec<CacheEntry>,
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to compile.
    pub misses: u64,
    /// Total nanoseconds spent compiling on misses.
    pub compile_ns: u64,
}

impl PlanCache {
    /// Cache holding at most `cap` compiled plans (>= 1).
    pub fn new(cap: usize) -> Self {
        PlanCache {
            cap: cap.max(1),
            tick: 0,
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            compile_ns: 0,
        }
    }

    /// Compiled plans currently resident.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plan is resident yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch the plan for (`key`, `mapping`), compiling (and caching)
    /// it on a miss; evicts the least-recently-used entry when full. A
    /// hit requires the stored mapping to match, not just the hash.
    pub fn get_or_compile<F>(
        &mut self,
        key: u64,
        mapping: &Mapping,
        compile: F,
    ) -> Result<&QuantNet>
    where
        F: FnOnce() -> Result<QuantNet>,
    {
        self.tick += 1;
        if let Some(i) = self
            .entries
            .iter()
            .position(|e| e.key == key && e.mapping == *mapping)
        {
            self.hits += 1;
            self.entries[i].last_used = self.tick;
            return Ok(&self.entries[i].net);
        }
        self.misses += 1;
        let t0 = std::time::Instant::now();
        let net = compile()?;
        self.compile_ns += t0.elapsed().as_nanos() as u64;
        if self.entries.len() >= self.cap {
            // min_by_key is Some exactly because len >= cap >= 1
            if let Some(lru) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
            {
                self.entries.swap_remove(lru);
            }
        }
        let tick = self.tick;
        self.entries.push(CacheEntry { key, mapping: mapping.clone(), last_used: tick, net });
        let last = self.entries.len() - 1;
        Ok(&self.entries[last].net)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::hw::Platform;
    use crate::model::tinycnn;
    use crate::quant::{synth_mapping_n, synth_params, KernelBackend, ParamSet, QuantPlan};

    fn req(id: u64, arrival: u64, point: usize) -> Request {
        Request { id, arrival, sla: Sla::MinEnergy, point }
    }

    #[test]
    fn full_queue_flushes_on_push() {
        let mut b = Batcher::new(2, 1_000);
        assert!(b.push(req(0, 10, 3)).is_none());
        let batch = b.push(req(1, 20, 3)).expect("second push fills the batch");
        assert_eq!(batch.point, 3);
        assert_eq!(batch.flushed_at, 20);
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn distinct_mappings_never_share_a_batch() {
        let mut b = Batcher::new(2, 1_000);
        assert!(b.push(req(0, 10, 1)).is_none());
        assert!(b.push(req(1, 11, 2)).is_none());
        assert_eq!(b.pending(), 2);
        assert_eq!(b.next_deadline(), Some(1_010));
    }

    #[test]
    fn due_flushes_expired_queues_only() {
        let mut b = Batcher::new(8, 100);
        b.push(req(0, 10, 1));
        b.push(req(1, 500, 2));
        let out = b.due(110);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].point, 1);
        assert_eq!(out[0].flushed_at, 110);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn drain_empties_everything() {
        let mut b = Batcher::new(8, 100);
        b.push(req(0, 10, 2));
        b.push(req(1, 20, 1));
        let out = b.drain(999);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].point, 1, "drain flushes in point order");
        assert_eq!(b.pending(), 0);
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn plan_cache_hits_and_lru_eviction() {
        let g = tinycnn();
        let p = Platform::diana();
        let (names, values) = synth_params(&g, 3);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let maps: Vec<_> = (0..3u64).map(|s| synth_mapping_n(&g, 2, s)).collect();
        let keys: Vec<u64> = maps
            .iter()
            .map(|m| QuantPlan::cache_key(&g.name, &p.name, m, KernelBackend::Auto))
            .collect();
        let mut cache = PlanCache::new(2);
        for (k, m) in keys.iter().zip(&maps) {
            cache
                .get_or_compile(*k, m, || QuantNet::compile_params(&params, &g, m, &p))
                .unwrap();
        }
        assert_eq!((cache.hits, cache.misses), (0, 3));
        assert_eq!(cache.len(), 2, "cap 2 evicted the LRU entry");
        // keys[1] and keys[2] are resident; keys[0] was evicted
        cache
            .get_or_compile(keys[2], &maps[2], || {
                QuantNet::compile_params(&params, &g, &maps[2], &p)
            })
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 3));
        cache
            .get_or_compile(keys[0], &maps[0], || {
                QuantNet::compile_params(&params, &g, &maps[0], &p)
            })
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 4));
        assert!(cache.compile_ns > 0);
        // identity is the mapping, not the hash: the same key with a
        // different mapping must be treated as a miss, never a hit
        cache
            .get_or_compile(keys[0], &maps[1], || {
                QuantNet::compile_params(&params, &g, &maps[1], &p)
            })
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 5));
    }
}
