//! Request queue + dynamic batcher + LRU plan cache.
//!
//! The [`Batcher`] coalesces requests that dispatched onto the *same*
//! `(model, frontier point)` pair — same graph, same compiled plan —
//! into batches, flushing a queue when it reaches `max_batch` requests
//! or when its oldest request has waited `max_wait` simulated cycles.
//! Batches never mix models: the queue key carries the model index, so
//! a multi-model serve plane shares one batcher without cross-model
//! contamination. When several queues are ripe at once, flush order is
//! deficit-round-robin across models — among equal deadlines the model
//! with the fewest requests flushed so far goes first — so a chatty
//! model cannot starve a quiet one's expired batches. With a single
//! model every counter ties and the ordering degenerates to the
//! historical (deadline, point) order, keeping old digests stable.
//! All bookkeeping is in virtual (simulated-cycle) time and iteration
//! order is `BTreeMap`-stable, so batch composition is deterministic
//! for a given request stream.
//!
//! The [`PlanCache`] keeps up to `cap` compiled [`QuantNet`] plans,
//! keyed by [`QuantPlan::cache_key`](crate::quant::QuantPlan::cache_key)
//! and evicted least-recently-used:
//! a serve run touching a handful of frontier mappings compiles each
//! plan once and replays it for every later batch (hit/miss counts and
//! compile time feed the serve dashboard and `bench_infer`).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::Mapping;
use crate::quant::QuantNet;

use super::dispatch::Sla;

/// One inference request in the closed-loop driver.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Request id; doubles as the synthetic-input sample index.
    pub id: u64,
    /// Arrival time, simulated cycles.
    pub arrival: u64,
    /// The request's SLA (drives dispatch and hit-rate accounting).
    pub sla: Sla,
    /// Model index in the serving set (0 on single-model planes).
    pub model: u32,
    /// Frontier index the dispatcher chose for this request.
    pub point: usize,
}

/// A flushed batch: requests sharing one (model, frontier mapping).
#[derive(Clone, Debug)]
pub struct Batch {
    /// Model index all member requests target.
    pub model: u32,
    /// Frontier index all member requests dispatched to.
    pub point: usize,
    /// Virtual time the batch left the queue.
    pub flushed_at: u64,
    /// Member requests, in arrival order.
    pub requests: Vec<Request>,
}

/// Dynamic same-(model, mapping) batcher (see module docs).
pub struct Batcher {
    max_batch: usize,
    max_wait: u64,
    queues: BTreeMap<(u32, usize), Vec<Request>>,
    /// Requests flushed so far per model — the deficit-round-robin
    /// state: among equally-ripe queues, the least-served model first.
    served: BTreeMap<u32, u64>,
}

impl Batcher {
    /// `max_batch` >= 1 requests per flush; `max_wait` in simulated
    /// cycles (0 flushes every request immediately — unbatched mode).
    pub fn new(max_batch: usize, max_wait: u64) -> Self {
        Batcher {
            max_batch: max_batch.max(1),
            max_wait,
            queues: BTreeMap::new(),
            served: BTreeMap::new(),
        }
    }

    /// Requests currently queued across all (model, mapping) queues.
    pub fn pending(&self) -> usize {
        self.queues.values().map(Vec::len).sum()
    }

    /// Requests currently queued on `(model, point)`'s queue (the obs
    /// layer classifies a push as batch-open vs batch-join with this).
    pub fn pending_for(&self, model: u32, point: usize) -> usize {
        self.queues.get(&(model, point)).map_or(0, Vec::len)
    }

    /// Requests flushed so far for `model` (the fairness counter).
    pub fn served_for(&self, model: u32) -> u64 {
        self.served.get(&model).copied().unwrap_or(0)
    }

    /// Enqueue one request; returns the flushed batch if its queue just
    /// reached `max_batch`.
    pub fn push(&mut self, r: Request) -> Option<Batch> {
        let (key, now) = ((r.model, r.point), r.arrival);
        let q = self.queues.entry(key).or_default();
        q.push(r);
        if q.len() >= self.max_batch {
            return Some(self.flush(key, now));
        }
        None
    }

    /// Earliest flush deadline over all non-empty queues (oldest
    /// member's arrival + `max_wait`).
    pub fn next_deadline(&self) -> Option<u64> {
        // saturating: max_wait = u64::MAX is a legal "never flush on
        // wait" setting and must not wrap into an immediate deadline
        self.queues
            .values()
            .filter_map(|q| q.first().map(|r| r.arrival.saturating_add(self.max_wait)))
            .min()
    }

    /// Flush every queue whose deadline has passed at `now`, oldest
    /// deadline first. Ties break deficit-round-robin: the model with
    /// the fewest requests flushed so far goes first (then model, then
    /// point — fully deterministic). With one model the counters all
    /// tie and this is the historical (deadline, point) order.
    pub fn due(&mut self, now: u64) -> Vec<Batch> {
        let mut ripe: Vec<(u64, u64, u32, usize)> = self
            .queues
            .iter()
            .filter_map(|(&(model, point), q)| {
                q.first()
                    .map(|r| {
                        (r.arrival.saturating_add(self.max_wait), self.served_for(model),
                         model, point)
                    })
                    .filter(|&(deadline, ..)| deadline <= now)
            })
            .collect();
        ripe.sort_unstable();
        // re-rank after every flush: a flushed model's counter grows,
        // so remaining ties rotate to the next least-served model
        let mut out = Vec::with_capacity(ripe.len());
        while !ripe.is_empty() {
            let (_, _, model, point) = ripe.remove(0);
            out.push(self.flush((model, point), now));
            for entry in ripe.iter_mut() {
                entry.1 = self.served_for(entry.2);
            }
            ripe.sort_unstable();
        }
        out
    }

    /// Remove up to `k` queued requests, oldest first by (arrival, id)
    /// across all queues — the work-stealing donor side. Each victim
    /// queue keeps its remaining requests in order, so deadlines stay
    /// monotone for what stays behind.
    pub fn steal_oldest(&mut self, k: usize) -> Vec<Request> {
        let mut all: Vec<(u64, u64, (u32, usize))> = self
            .queues
            .iter()
            .flat_map(|(&key, q)| q.iter().map(move |r| (r.arrival, r.id, key)))
            .collect();
        all.sort_unstable();
        all.truncate(k);
        let mut stolen = Vec::with_capacity(all.len());
        for (_, id, key) in all {
            if let Some(q) = self.queues.get_mut(&key) {
                if let Some(i) = q.iter().position(|r| r.id == id) {
                    stolen.push(q.remove(i));
                }
                if q.is_empty() {
                    self.queues.remove(&key);
                }
            }
        }
        stolen
    }

    /// Flush everything that remains, in (model, point) order.
    pub fn drain(&mut self, now: u64) -> Vec<Batch> {
        let keys: Vec<(u32, usize)> = self.queues.keys().copied().collect();
        keys.into_iter().map(|k| self.flush(k, now)).collect()
    }

    fn flush(&mut self, key: (u32, usize), now: u64) -> Batch {
        let requests = self.queues.remove(&key).unwrap_or_default();
        *self.served.entry(key.0).or_insert(0) += requests.len() as u64;
        Batch { model: key.0, point: key.1, flushed_at: now, requests }
    }
}

// ---- LRU plan cache ---------------------------------------------------

struct CacheEntry {
    key: u64,
    /// The mapping the plan was compiled for: verified on every hit so
    /// a (astronomically unlikely) 64-bit hash collision can never hand
    /// back the wrong compiled plan — the hash is a fast filter, the
    /// mapping is the identity.
    mapping: Mapping,
    last_used: u64,
    net: QuantNet,
}

/// LRU cache of compiled plans, keyed by
/// [`QuantPlan::cache_key`](crate::quant::QuantPlan::cache_key).
/// Plans own their data outright, so the cache can live as long as the
/// owner likes — e.g. across every call of a
/// [`Session`](crate::api::Session).
pub struct PlanCache {
    cap: usize,
    tick: u64,
    entries: Vec<CacheEntry>,
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to compile.
    pub misses: u64,
    /// Total nanoseconds spent compiling on misses.
    pub compile_ns: u64,
}

impl PlanCache {
    /// Cache holding at most `cap` compiled plans (>= 1).
    pub fn new(cap: usize) -> Self {
        PlanCache {
            cap: cap.max(1),
            tick: 0,
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            compile_ns: 0,
        }
    }

    /// Compiled plans currently resident.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plan is resident yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch the plan for (`key`, `mapping`), compiling (and caching)
    /// it on a miss; evicts the least-recently-used entry when full. A
    /// hit requires the stored mapping to match, not just the hash.
    pub fn get_or_compile<F>(
        &mut self,
        key: u64,
        mapping: &Mapping,
        compile: F,
    ) -> Result<&QuantNet>
    where
        F: FnOnce() -> Result<QuantNet>,
    {
        self.tick += 1;
        if let Some(i) = self
            .entries
            .iter()
            .position(|e| e.key == key && e.mapping == *mapping)
        {
            self.hits += 1;
            self.entries[i].last_used = self.tick;
            return Ok(&self.entries[i].net);
        }
        self.misses += 1;
        let t0 = std::time::Instant::now();
        let net = compile()?;
        self.compile_ns += t0.elapsed().as_nanos() as u64;
        if self.entries.len() >= self.cap {
            // min_by_key is Some exactly because len >= cap >= 1
            if let Some(lru) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
            {
                self.entries.swap_remove(lru);
            }
        }
        let tick = self.tick;
        self.entries.push(CacheEntry { key, mapping: mapping.clone(), last_used: tick, net });
        let last = self.entries.len() - 1;
        Ok(&self.entries[last].net)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::hw::Platform;
    use crate::model::tinycnn;
    use crate::quant::{synth_mapping_n, synth_params, KernelBackend, ParamSet, QuantPlan};

    fn req(id: u64, arrival: u64, point: usize) -> Request {
        Request { id, arrival, sla: Sla::MinEnergy, model: 0, point }
    }

    fn mreq(id: u64, arrival: u64, model: u32, point: usize) -> Request {
        Request { id, arrival, sla: Sla::MinEnergy, model, point }
    }

    #[test]
    fn full_queue_flushes_on_push() {
        let mut b = Batcher::new(2, 1_000);
        assert!(b.push(req(0, 10, 3)).is_none());
        let batch = b.push(req(1, 20, 3)).expect("second push fills the batch");
        assert_eq!(batch.point, 3);
        assert_eq!(batch.flushed_at, 20);
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn distinct_mappings_never_share_a_batch() {
        let mut b = Batcher::new(2, 1_000);
        assert!(b.push(req(0, 10, 1)).is_none());
        assert!(b.push(req(1, 11, 2)).is_none());
        assert_eq!(b.pending(), 2);
        assert_eq!(b.next_deadline(), Some(1_010));
    }

    #[test]
    fn due_flushes_expired_queues_only() {
        let mut b = Batcher::new(8, 100);
        b.push(req(0, 10, 1));
        b.push(req(1, 500, 2));
        let out = b.due(110);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].point, 1);
        assert_eq!(out[0].flushed_at, 110);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn drain_empties_everything() {
        let mut b = Batcher::new(8, 100);
        b.push(req(0, 10, 2));
        b.push(req(1, 20, 1));
        let out = b.drain(999);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].point, 1, "drain flushes in point order");
        assert_eq!(b.pending(), 0);
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn batches_never_mix_models() {
        let mut b = Batcher::new(4, 1_000);
        assert!(b.push(mreq(0, 10, 0, 3)).is_none());
        assert!(b.push(mreq(1, 11, 1, 3)).is_none());
        // same frontier point, different models: two distinct queues
        assert_eq!(b.pending_for(0, 3), 1);
        assert_eq!(b.pending_for(1, 3), 1);
        let out = b.drain(100);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|batch| {
            batch.requests.iter().all(|r| r.model == batch.model)
        }));
    }

    #[test]
    fn due_ties_rotate_to_least_served_model() {
        let mut b = Batcher::new(8, 100);
        // model 1 has been served 4 requests already (fills a batch)
        for id in 0..4 {
            b.push(mreq(id, 1, 1, 0));
        }
        assert_eq!(b.drain(1).len(), 1);
        assert_eq!(b.served_for(1), 4);
        // both models ripen at the same deadline; the never-served
        // model 0 must flush first despite the larger model index
        b.push(mreq(10, 50, 1, 0));
        b.push(mreq(11, 50, 0, 0));
        let out = b.due(200);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].model, 0, "least-served model flushes first");
        assert_eq!(out[1].model, 1);
        // earlier deadlines still beat fairness: an expired queue of
        // the busy model precedes a fresher queue of the quiet one
        b.push(mreq(12, 300, 1, 0));
        b.push(mreq(13, 350, 0, 0));
        let out = b.due(1_000);
        assert_eq!(out[0].model, 1, "deadline order dominates the tie-break");
        assert_eq!(out[1].model, 0);
    }

    #[test]
    fn plan_cache_hits_and_lru_eviction() {
        let g = tinycnn();
        let p = Platform::diana();
        let (names, values) = synth_params(&g, 3);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let maps: Vec<_> = (0..3u64).map(|s| synth_mapping_n(&g, 2, s)).collect();
        let keys: Vec<u64> = maps
            .iter()
            .map(|m| QuantPlan::cache_key(&g.name, g.spec_hash(), &p.name, m, KernelBackend::Auto))
            .collect();
        let mut cache = PlanCache::new(2);
        for (k, m) in keys.iter().zip(&maps) {
            cache
                .get_or_compile(*k, m, || QuantNet::compile_params(&params, &g, m, &p))
                .unwrap();
        }
        assert_eq!((cache.hits, cache.misses), (0, 3));
        assert_eq!(cache.len(), 2, "cap 2 evicted the LRU entry");
        // keys[1] and keys[2] are resident; keys[0] was evicted
        cache
            .get_or_compile(keys[2], &maps[2], || {
                QuantNet::compile_params(&params, &g, &maps[2], &p)
            })
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 3));
        cache
            .get_or_compile(keys[0], &maps[0], || {
                QuantNet::compile_params(&params, &g, &maps[0], &p)
            })
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 4));
        assert!(cache.compile_ns > 0);
        // identity is the mapping, not the hash: the same key with a
        // different mapping must be treated as a miss, never a hit
        cache
            .get_or_compile(keys[0], &maps[1], || {
                QuantNet::compile_params(&params, &g, &maps[1], &p)
            })
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 5));
    }
}
