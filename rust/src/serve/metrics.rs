//! Serve-side accounting: per-request outcomes, per-mapping
//! aggregation, and the `serve-report` dashboard.
//!
//! The collector ([`ServeMetrics`]) records one [`RequestOutcome`] per
//! served request in virtual (simulated-cycle) time and accumulates
//! every run counter in an [`obs::Registry`](crate::obs::Registry)
//! (named counters + raw latency histograms — see [`crate::obs::ctr`]
//! and [`crate::obs::hist`]), then folds everything into a
//! [`ServeReport`]: one row per frontier mapping (requests, mean batch
//! size, p50/p95 queue+compute latency, simulated energy, SLA
//! hit-rate), per-tenant rows (interactive vs batch — ROADMAP item 2),
//! and run-level totals (throughput over engine wall time, plan-cache
//! hits/misses and compile time, virtual makespan). Reports serialize
//! through the versioned store envelope so `serve-report` can render a
//! dashboard from a past run without re-serving.
//!
//! Fault accounting rides along: the report carries the injected-fault,
//! batch-abort, retry, shed and failed counters plus a degraded-service
//! p95, and every request the driver synthesized is accounted exactly
//! once as served, shed, or failed ([`ServeReport::accounted`]).
//! [`ServeReport::deterministic_digest`] hashes everything *except* the
//! two wall-clock-derived fields (`throughput_img_s`,
//! `plan_compile_ms`), so two runs with the same seed, opts and fault
//! plan agree digest-for-digest even though engine wall time differs.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::exp::store;
use crate::obs::{ctr, hist, Registry};
use crate::util::json::Json;

use super::dispatch::Sla;

/// Bump when the serve-report layout changes; [`load_report`] refuses
/// files written under any other version. v2 added the fault/admission
/// accounting fields (`faults_injected` … `degraded_p95_ms`).
pub const SERVE_SCHEMA: u32 = 2;

/// Additive revision within [`SERVE_SCHEMA`]: minor bumps add optional
/// fields that old readers may ignore and old files may lack. v2.1
/// added the run-level queue-wait / engine-compute latency split
/// (`mean_queue_ms`, `mean_compute_ms`); v2.2 added the per-tenant
/// rows (`tenant_rows`); v2.3 added the per-model rows (`model_rows`,
/// the multi-model serve plane). Loaders default all of them when
/// reading an older file.
pub const SERVE_SCHEMA_MINOR: u32 = 3;

/// Serving tenant class, derived from the request's SLA: latency-budget
/// requests are the interactive tenant, min-energy requests the batch
/// tenant — the same convention the trace synthesizer uses for its
/// `tenant` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tenant {
    /// Latency-budget requests.
    Interactive,
    /// Min-energy (throughput/batch) requests.
    Batch,
}

impl Tenant {
    /// The tenant class of a request with SLA `sla`.
    pub fn from_sla(sla: &Sla) -> Tenant {
        match sla {
            Sla::MinEnergy => Tenant::Batch,
            Sla::LatencyBudget(_) => Tenant::Interactive,
        }
    }

    /// Dashboard/JSON name (matches the trace-file `tenant` strings).
    pub fn name(self) -> &'static str {
        match self {
            Tenant::Interactive => "interactive",
            Tenant::Batch => "batch",
        }
    }

    /// Registry counter for this tenant's shed requests.
    pub fn shed_counter(self) -> &'static str {
        match self {
            Tenant::Interactive => ctr::SHED_INTERACTIVE,
            Tenant::Batch => ctr::SHED_BATCH,
        }
    }

    /// Both tenants, in report order.
    pub const ALL: [Tenant; 2] = [Tenant::Interactive, Tenant::Batch];
}

/// One served request, in virtual time.
#[derive(Clone, Copy, Debug)]
pub struct RequestOutcome {
    /// Request id.
    pub id: u64,
    /// Model index in the serving set (0 on single-model planes).
    pub model: u32,
    /// Frontier index the request was served under (point indices are
    /// per-model: two models may both have a point 0).
    pub point: usize,
    /// Cycles spent queued (batching wait + device contention).
    pub queue_cycles: u64,
    /// Cycles of the batch computation that served the request.
    pub compute_cycles: u64,
    /// Whether queue + compute latency met the request's SLA.
    pub sla_met: bool,
    /// Size of the batch that carried the request.
    pub batch_size: usize,
    /// Simulated energy attributed to the request, uJ.
    pub energy_uj: f64,
    /// Whether the request received degraded service: served on a
    /// degraded-mode re-mapping, stretched by a derated unit, retried
    /// after a batch abort, or force-routed by the overload controller.
    pub degraded: bool,
    /// Tenant class ([`Tenant::from_sla`] of the request's SLA).
    pub tenant: Tenant,
}

/// Collector filled by the closed-loop serve driver: the per-request
/// outcome list plus the run's counter/histogram [`Registry`]. Every
/// counter the drivers used to bump as an ad-hoc field now lives in
/// the registry under a [`crate::obs::ctr`] name, and [`ServeMetrics::report`]
/// reads it back from there.
pub struct ServeMetrics {
    outcomes: Vec<RequestOutcome>,
    reg: Registry,
}

impl ServeMetrics {
    pub fn new() -> Self {
        ServeMetrics { outcomes: Vec::new(), reg: Registry::new() }
    }

    /// The run's counter/histogram registry (read side).
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// The run's counter/histogram registry (the drivers' bump site).
    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.reg
    }

    /// Record one executed batch's wall-clock engine time.
    pub fn record_batch(&mut self, wall_ns: u64) {
        self.reg.inc(ctr::BATCHES);
        self.reg.add(ctr::ENGINE_WALL_NS, wall_ns);
    }

    /// Record one served request.
    pub fn record(&mut self, o: RequestOutcome) {
        self.reg
            .observe(hist::LATENCY_CYCLES, (o.queue_cycles + o.compute_cycles) as f64);
        self.reg.observe(hist::QUEUE_CYCLES, o.queue_cycles as f64);
        self.reg.observe(hist::COMPUTE_CYCLES, o.compute_cycles as f64);
        self.outcomes.push(o);
    }

    /// Requests recorded so far.
    pub fn requests(&self) -> usize {
        self.outcomes.len()
    }

    /// The recorded per-request outcomes (the cluster driver reads
    /// these back for per-tenant attribution).
    pub fn outcomes(&self) -> &[RequestOutcome] {
        &self.outcomes
    }

    /// Fold the collected outcomes into a renderable report. `labels`
    /// are the frontier point labels (row names); `f_clk_hz` converts
    /// cycles to milliseconds for the dashboard. Single-model shim over
    /// [`ServeMetrics::report_multi`] — row labels stay unprefixed, so
    /// pre-multi-model reports (and their digests) are unchanged.
    pub fn report(
        &self,
        model: &str,
        platform: &str,
        threads: usize,
        labels: &[String],
        f_clk_hz: f64,
    ) -> ServeReport {
        self.report_multi(&[(model.to_string(), labels.to_vec())], platform, threads, f_clk_hz)
    }

    /// Multi-model fold: `models` holds one (name, frontier labels)
    /// pair per model index, matching [`RequestOutcome::model`]. With
    /// several models the per-mapping rows are labeled
    /// `model:label` (point indices collide across models, names do
    /// not) and a per-model summary table rides in
    /// [`ServeReport::model_rows`].
    pub fn report_multi(
        &self,
        models: &[(String, Vec<String>)],
        platform: &str,
        threads: usize,
        f_clk_hz: f64,
    ) -> ServeReport {
        let to_ms = |cycles: u64| cycles as f64 / f_clk_hz * 1e3;
        let to_ms_f = |cycles: f64| cycles / f_clk_hz * 1e3;
        let multi = models.len() > 1;
        let mut rows: Vec<PointRow> = Vec::new();
        let mut model_rows: Vec<ModelRow> = Vec::new();
        for (mi, (mname, labels)) in models.iter().enumerate() {
            for (point, label) in labels.iter().enumerate() {
                let outs: Vec<&RequestOutcome> = self
                    .outcomes
                    .iter()
                    .filter(|o| o.model as usize == mi && o.point == point)
                    .collect();
                if outs.is_empty() {
                    continue;
                }
                let mut lats: Vec<u64> =
                    outs.iter().map(|o| o.queue_cycles + o.compute_cycles).collect();
                lats.sort_unstable();
                let batch_sum: usize = outs.iter().map(|o| o.batch_size).sum();
                rows.push(PointRow {
                    label: if multi { format!("{mname}:{label}") } else { label.clone() },
                    requests: outs.len(),
                    sla_hits: outs.iter().filter(|o| o.sla_met).count(),
                    mean_batch: batch_sum as f64 / outs.len() as f64,
                    p50_ms: to_ms(percentile(&lats, 50)),
                    p95_ms: to_ms(percentile(&lats, 95)),
                    energy_uj: outs.iter().map(|o| o.energy_uj).sum(),
                });
            }
            let outs: Vec<&RequestOutcome> =
                self.outcomes.iter().filter(|o| o.model as usize == mi).collect();
            if !outs.is_empty() {
                let mut lats: Vec<u64> =
                    outs.iter().map(|o| o.queue_cycles + o.compute_cycles).collect();
                lats.sort_unstable();
                model_rows.push(ModelRow {
                    model: mname.clone(),
                    requests: outs.len(),
                    sla_hits: outs.iter().filter(|o| o.sla_met).count(),
                    p50_ms: to_ms(percentile(&lats, 50)),
                    p95_ms: to_ms(percentile(&lats, 95)),
                    energy_uj: outs.iter().map(|o| o.energy_uj).sum(),
                });
            }
        }
        let mut tenant_rows: Vec<TenantLatencyRow> = Vec::new();
        for t in Tenant::ALL {
            let outs: Vec<&RequestOutcome> =
                self.outcomes.iter().filter(|o| o.tenant == t).collect();
            let shed = self.reg.counter(t.shed_counter());
            if outs.is_empty() && shed == 0 {
                continue;
            }
            let mut lats: Vec<u64> =
                outs.iter().map(|o| o.queue_cycles + o.compute_cycles).collect();
            lats.sort_unstable();
            tenant_rows.push(TenantLatencyRow {
                tenant: t.name().to_string(),
                requests: outs.len(),
                sla_hits: outs.iter().filter(|o| o.sla_met).count(),
                shed,
                p50_ms: to_ms(percentile(&lats, 50)),
                p95_ms: to_ms(percentile(&lats, 95)),
            });
        }
        let n = self.outcomes.len();
        let mut deg_lats: Vec<u64> = self
            .outcomes
            .iter()
            .filter(|o| o.degraded)
            .map(|o| o.queue_cycles + o.compute_cycles)
            .collect();
        deg_lats.sort_unstable();
        let wall_s = self.reg.counter(ctr::ENGINE_WALL_NS) as f64 * 1e-9;
        ServeReport {
            model: models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join("+"),
            platform: platform.to_string(),
            threads,
            rows,
            tenant_rows,
            model_rows,
            total_requests: n,
            total_batches: self.reg.counter(ctr::BATCHES) as usize,
            p50_ms: to_ms_f(self.reg.percentile(hist::LATENCY_CYCLES, 50)),
            p95_ms: to_ms_f(self.reg.percentile(hist::LATENCY_CYCLES, 95)),
            sla_hit_rate: if n == 0 {
                1.0
            } else {
                self.outcomes.iter().filter(|o| o.sla_met).count() as f64 / n as f64
            },
            mean_queue_ms: if n == 0 {
                0.0
            } else {
                to_ms_f(self.reg.sum(hist::QUEUE_CYCLES)) / n as f64
            },
            mean_compute_ms: if n == 0 {
                0.0
            } else {
                to_ms_f(self.reg.sum(hist::COMPUTE_CYCLES)) / n as f64
            },
            throughput_img_s: if wall_s > 0.0 { n as f64 / wall_s } else { 0.0 },
            sim_energy_uj: self.outcomes.iter().map(|o| o.energy_uj).sum(),
            plan_hits: self.reg.counter(ctr::PLAN_HITS),
            plan_misses: self.reg.counter(ctr::PLAN_MISSES),
            plan_compile_ms: self.reg.counter(ctr::PLAN_COMPILE_NS) as f64 * 1e-6,
            makespan_ms: to_ms(self.reg.counter(ctr::END_CYCLE)),
            faults_injected: self.reg.counter(ctr::FAULTS_INJECTED),
            batch_aborts: self.reg.counter(ctr::BATCH_ABORTS),
            retries: self.reg.counter(ctr::RETRIES),
            shed_requests: self.reg.counter(ctr::SHED),
            failed_requests: self.reg.counter(ctr::FAILED),
            degraded_requests: deg_lats.len(),
            degraded_p95_ms: to_ms(percentile(&deg_lats, 95)),
        }
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// `p`-th percentile of an ascending-sorted slice (nearest-rank).
fn percentile(sorted: &[u64], p: usize) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (p * sorted.len()).div_ceil(100).max(1);
    sorted[rank - 1]
}

/// One dashboard row: aggregates for a single frontier mapping.
#[derive(Clone, Debug)]
pub struct PointRow {
    /// Frontier point label.
    pub label: String,
    /// Requests served under this mapping.
    pub requests: usize,
    /// Requests whose end-to-end latency met their SLA.
    pub sla_hits: usize,
    /// Mean batch size over this mapping's requests.
    pub mean_batch: f64,
    /// Median queue+compute latency, ms.
    pub p50_ms: f64,
    /// 95th-percentile queue+compute latency, ms.
    pub p95_ms: f64,
    /// Total simulated energy, uJ.
    pub energy_uj: f64,
}

/// One per-tenant dashboard row (single-replica path — the cluster
/// report carries its own tenant table). Added in v2.2; excluded from
/// [`ServeReport::deterministic_digest`] so v2.x reports of one run
/// stay digest-compatible (the rows are derived from the
/// already-digested outcome stream and shed counter).
#[derive(Clone, Debug)]
pub struct TenantLatencyRow {
    /// Tenant name (`interactive` | `batch`).
    pub tenant: String,
    /// Requests served for this tenant.
    pub requests: usize,
    /// Served requests that met their SLA.
    pub sla_hits: usize,
    /// Requests of this tenant shed by admission control.
    pub shed: u64,
    /// Median queue+compute latency, ms.
    pub p50_ms: f64,
    /// 95th-percentile queue+compute latency, ms.
    pub p95_ms: f64,
}

/// One per-model dashboard row (multi-model serve plane). Added in
/// v2.3; excluded from [`ServeReport::deterministic_digest`] for the
/// same reason as the tenant rows — derived from the already-digested
/// outcome stream.
#[derive(Clone, Debug)]
pub struct ModelRow {
    /// Model name.
    pub model: String,
    /// Requests served for this model.
    pub requests: usize,
    /// Served requests that met their SLA.
    pub sla_hits: usize,
    /// Median queue+compute latency, ms.
    pub p50_ms: f64,
    /// 95th-percentile queue+compute latency, ms.
    pub p95_ms: f64,
    /// Total simulated energy attributed to this model, uJ.
    pub energy_uj: f64,
}

/// A finished serve run, ready to render or persist.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Model served.
    pub model: String,
    /// Platform served on.
    pub platform: String,
    /// Worker threads the engine ran with.
    pub threads: usize,
    /// Per-mapping rows (only mappings that served requests).
    pub rows: Vec<PointRow>,
    /// Per-tenant rows (only tenants that appeared in the run).
    pub tenant_rows: Vec<TenantLatencyRow>,
    /// Per-model rows (only models that served requests; one row on a
    /// single-model plane). Added in v2.3; derived, not digested.
    pub model_rows: Vec<ModelRow>,
    /// Requests served.
    pub total_requests: usize,
    /// Batches executed.
    pub total_batches: usize,
    /// Run-level median queue+compute latency, ms.
    pub p50_ms: f64,
    /// Run-level p95 queue+compute latency, ms.
    pub p95_ms: f64,
    /// Fraction of requests that met their SLA.
    pub sla_hit_rate: f64,
    /// Mean per-request queue wait (batching + device contention), ms.
    /// Added in v2.1; excluded from [`ServeReport::deterministic_digest`]
    /// so v2.0 and v2.1 reports of the same run digest identically.
    pub mean_queue_ms: f64,
    /// Mean per-request batch compute latency, ms. Added in v2.1;
    /// excluded from the digest for the same reason as `mean_queue_ms`.
    pub mean_compute_ms: f64,
    /// Engine throughput over wall-clock compute time, img/s.
    pub throughput_img_s: f64,
    /// Total simulated energy, uJ.
    pub sim_energy_uj: f64,
    /// Plan-cache lookups served without compiling.
    pub plan_hits: u64,
    /// Plan-cache lookups that compiled.
    pub plan_misses: u64,
    /// Wall-clock spent compiling plans, ms.
    pub plan_compile_ms: f64,
    /// Virtual completion time of the run, ms.
    pub makespan_ms: f64,
    /// Fault events in the resolved plan for this run.
    pub faults_injected: u64,
    /// Batches aborted because a unit died mid-flight.
    pub batch_aborts: u64,
    /// Request re-enqueues (abort recovery + no-dispatchable-point).
    pub retries: u64,
    /// Requests shed by the overload admission controller.
    pub shed_requests: u64,
    /// Requests dropped after exhausting their retry budget.
    pub failed_requests: u64,
    /// Requests that received degraded service (see
    /// [`RequestOutcome::degraded`]).
    pub degraded_requests: usize,
    /// p95 queue+compute latency over degraded requests only, ms
    /// (0 when nothing was degraded).
    pub degraded_p95_ms: f64,
}

impl ServeReport {
    /// Render the `serve-report` dashboard (markdown).
    pub fn dashboard(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "# serve report — {} on {} ({} threads)\n",
            self.model, self.platform, self.threads
        );
        let _ = writeln!(
            s,
            "requests {} | batches {} | throughput {:.1} img/s (engine wall) | \
             SLA hit-rate {:.1}%",
            self.total_requests,
            self.total_batches,
            self.throughput_img_s,
            100.0 * self.sla_hit_rate
        );
        let _ = writeln!(
            s,
            "queue+compute latency p50 {:.3} ms | p95 {:.3} ms | simulated energy {:.1} uJ | \
             makespan {:.3} ms",
            self.p50_ms, self.p95_ms, self.sim_energy_uj, self.makespan_ms
        );
        let _ = writeln!(
            s,
            "latency split: queue wait mean {:.3} ms | engine compute mean {:.3} ms",
            self.mean_queue_ms, self.mean_compute_ms
        );
        let _ = writeln!(
            s,
            "plan cache: {} hits / {} misses | compile {:.2} ms",
            self.plan_hits, self.plan_misses, self.plan_compile_ms
        );
        let _ = writeln!(
            s,
            "faults: {} injected | {} batch aborts | {} retries | {} shed | {} failed | \
             degraded {} req p95 {:.3} ms\n",
            self.faults_injected,
            self.batch_aborts,
            self.retries,
            self.shed_requests,
            self.failed_requests,
            self.degraded_requests,
            self.degraded_p95_ms
        );
        let _ = writeln!(
            s,
            "| mapping | req | mean batch | p50 [ms] | p95 [ms] | E [uJ] | SLA |"
        );
        let _ = writeln!(
            s,
            "|---------|-----|------------|----------|----------|--------|-----|"
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "| {} | {} | {:.2} | {:.3} | {:.3} | {:.1} | {:.1}% |",
                r.label,
                r.requests,
                r.mean_batch,
                r.p50_ms,
                r.p95_ms,
                r.energy_uj,
                100.0 * r.sla_hits as f64 / r.requests.max(1) as f64
            );
        }
        if self.model_rows.len() > 1 {
            let _ = writeln!(s);
            let _ = writeln!(s, "| model | req | p50 [ms] | p95 [ms] | E [uJ] | SLA |");
            let _ = writeln!(s, "|-------|-----|----------|----------|--------|-----|");
            for m in &self.model_rows {
                let _ = writeln!(
                    s,
                    "| {} | {} | {:.3} | {:.3} | {:.1} | {:.1}% |",
                    m.model,
                    m.requests,
                    m.p50_ms,
                    m.p95_ms,
                    m.energy_uj,
                    100.0 * m.sla_hits as f64 / m.requests.max(1) as f64
                );
            }
        }
        if !self.tenant_rows.is_empty() {
            let _ = writeln!(s);
            let _ = writeln!(s, "| tenant | req | shed | p50 [ms] | p95 [ms] | SLA |");
            let _ = writeln!(s, "|--------|-----|------|----------|----------|-----|");
            for t in &self.tenant_rows {
                let _ = writeln!(
                    s,
                    "| {} | {} | {} | {:.3} | {:.3} | {:.1}% |",
                    t.tenant,
                    t.requests,
                    t.shed,
                    t.p50_ms,
                    t.p95_ms,
                    100.0 * t.sla_hits as f64 / t.requests.max(1) as f64
                );
            }
        }
        s
    }

    /// Requests this run accounted for: served + shed + failed. The
    /// serve driver guarantees this equals the synthesized stream
    /// length — no request is ever silently lost, faults or not.
    pub fn accounted(&self) -> usize {
        self.total_requests + self.shed_requests as usize + self.failed_requests as usize
    }

    /// FNV-1a digest over every *virtual-time* field of the report —
    /// everything except `threads` (run configuration, not outcome)
    /// and the two wall-clock fields `throughput_img_s` /
    /// `plan_compile_ms`, which measure engine/compiler time and
    /// legitimately differ between identical runs. Two serve runs with
    /// the same model, platform, seed, opts and fault plan produce
    /// equal digests regardless of thread count or machine load.
    ///
    /// The v2.1 latency-split fields (`mean_queue_ms`,
    /// `mean_compute_ms`) and the v2.2 `tenant_rows` are also
    /// excluded: they are derived from the already-digested outcome
    /// stream, and excluding them keeps v2.0/v2.1/v2.2 reports of the
    /// same run digest-compatible.
    pub fn deterministic_digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(self.model.as_bytes());
        eat(self.platform.as_bytes());
        for r in &self.rows {
            eat(r.label.as_bytes());
            eat(&(r.requests as u64).to_le_bytes());
            eat(&(r.sla_hits as u64).to_le_bytes());
            eat(&r.mean_batch.to_bits().to_le_bytes());
            eat(&r.p50_ms.to_bits().to_le_bytes());
            eat(&r.p95_ms.to_bits().to_le_bytes());
            eat(&r.energy_uj.to_bits().to_le_bytes());
        }
        eat(&(self.total_requests as u64).to_le_bytes());
        eat(&(self.total_batches as u64).to_le_bytes());
        eat(&self.p50_ms.to_bits().to_le_bytes());
        eat(&self.p95_ms.to_bits().to_le_bytes());
        eat(&self.sla_hit_rate.to_bits().to_le_bytes());
        eat(&self.sim_energy_uj.to_bits().to_le_bytes());
        eat(&self.plan_hits.to_le_bytes());
        eat(&self.plan_misses.to_le_bytes());
        eat(&self.makespan_ms.to_bits().to_le_bytes());
        eat(&self.faults_injected.to_le_bytes());
        eat(&self.batch_aborts.to_le_bytes());
        eat(&self.retries.to_le_bytes());
        eat(&self.shed_requests.to_le_bytes());
        eat(&self.failed_requests.to_le_bytes());
        eat(&(self.degraded_requests as u64).to_le_bytes());
        eat(&self.degraded_p95_ms.to_bits().to_le_bytes());
        h
    }

    pub(crate) fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("label", Json::str(r.label.clone())),
                    ("requests", Json::num(r.requests as f64)),
                    ("sla_hits", Json::num(r.sla_hits as f64)),
                    ("mean_batch", Json::num(r.mean_batch)),
                    ("p50_ms", Json::num(r.p50_ms)),
                    ("p95_ms", Json::num(r.p95_ms)),
                    ("energy_uj", Json::num(r.energy_uj)),
                ])
            })
            .collect();
        let tenants = self
            .tenant_rows
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", Json::str(t.tenant.clone())),
                    ("requests", Json::num(t.requests as f64)),
                    ("sla_hits", Json::num(t.sla_hits as f64)),
                    ("shed", Json::num(t.shed as f64)),
                    ("p50_ms", Json::num(t.p50_ms)),
                    ("p95_ms", Json::num(t.p95_ms)),
                ])
            })
            .collect();
        let models = self
            .model_rows
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("model", Json::str(m.model.clone())),
                    ("requests", Json::num(m.requests as f64)),
                    ("sla_hits", Json::num(m.sla_hits as f64)),
                    ("p50_ms", Json::num(m.p50_ms)),
                    ("p95_ms", Json::num(m.p95_ms)),
                    ("energy_uj", Json::num(m.energy_uj)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("platform", Json::str(self.platform.clone())),
            ("threads", Json::num(self.threads as f64)),
            ("rows", Json::Arr(rows)),
            ("tenant_rows", Json::Arr(tenants)),
            ("model_rows", Json::Arr(models)),
            ("total_requests", Json::num(self.total_requests as f64)),
            ("total_batches", Json::num(self.total_batches as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("sla_hit_rate", Json::num(self.sla_hit_rate)),
            ("schema_minor", Json::num(SERVE_SCHEMA_MINOR as f64)),
            ("mean_queue_ms", Json::num(self.mean_queue_ms)),
            ("mean_compute_ms", Json::num(self.mean_compute_ms)),
            ("throughput_img_s", Json::num(self.throughput_img_s)),
            ("sim_energy_uj", Json::num(self.sim_energy_uj)),
            ("plan_hits", Json::num(self.plan_hits as f64)),
            ("plan_misses", Json::num(self.plan_misses as f64)),
            ("plan_compile_ms", Json::num(self.plan_compile_ms)),
            ("makespan_ms", Json::num(self.makespan_ms)),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("batch_aborts", Json::num(self.batch_aborts as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("shed_requests", Json::num(self.shed_requests as f64)),
            ("failed_requests", Json::num(self.failed_requests as f64)),
            ("degraded_requests", Json::num(self.degraded_requests as f64)),
            ("degraded_p95_ms", Json::num(self.degraded_p95_ms)),
        ])
    }

    pub(crate) fn from_json(v: &Json) -> Result<ServeReport> {
        let rows = v
            .req("rows")?
            .as_arr()
            .ok_or_else(|| anyhow!("serve report: rows must be an array"))?
            .iter()
            .map(|r| -> Result<PointRow> {
                Ok(PointRow {
                    label: r.req("label")?.as_str().unwrap_or("").to_string(),
                    requests: r.req_f64("requests")? as usize,
                    sla_hits: r.req_f64("sla_hits")? as usize,
                    mean_batch: r.req_f64("mean_batch")?,
                    p50_ms: r.req_f64("p50_ms")?,
                    p95_ms: r.req_f64("p95_ms")?,
                    energy_uj: r.req_f64("energy_uj")?,
                })
            })
            .collect::<Result<Vec<PointRow>>>()?;
        // v2.2 addition: lenient so v2.0/v2.1 files still load
        let tenant_rows = match v.get("tenant_rows").and_then(|t| t.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(|t| -> Result<TenantLatencyRow> {
                    Ok(TenantLatencyRow {
                        tenant: t.req("tenant")?.as_str().unwrap_or("").to_string(),
                        requests: t.req_f64("requests")? as usize,
                        sla_hits: t.req_f64("sla_hits")? as usize,
                        shed: t.req_f64("shed")? as u64,
                        p50_ms: t.req_f64("p50_ms")?,
                        p95_ms: t.req_f64("p95_ms")?,
                    })
                })
                .collect::<Result<Vec<TenantLatencyRow>>>()?,
            None => Vec::new(),
        };
        // v2.3 addition: lenient so v2.0..v2.2 files still load
        let model_rows = match v.get("model_rows").and_then(|t| t.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(|m| -> Result<ModelRow> {
                    Ok(ModelRow {
                        model: m.req("model")?.as_str().unwrap_or("").to_string(),
                        requests: m.req_f64("requests")? as usize,
                        sla_hits: m.req_f64("sla_hits")? as usize,
                        p50_ms: m.req_f64("p50_ms")?,
                        p95_ms: m.req_f64("p95_ms")?,
                        energy_uj: m.req_f64("energy_uj")?,
                    })
                })
                .collect::<Result<Vec<ModelRow>>>()?,
            None => Vec::new(),
        };
        Ok(ServeReport {
            model: v.req("model")?.as_str().unwrap_or("").to_string(),
            platform: v.req("platform")?.as_str().unwrap_or("").to_string(),
            threads: v.req_f64("threads")? as usize,
            rows,
            tenant_rows,
            model_rows,
            total_requests: v.req_f64("total_requests")? as usize,
            total_batches: v.req_f64("total_batches")? as usize,
            p50_ms: v.req_f64("p50_ms")?,
            p95_ms: v.req_f64("p95_ms")?,
            sla_hit_rate: v.req_f64("sla_hit_rate")?,
            // v2.1 additions: lenient so v2.0 files still load
            mean_queue_ms: v.get("mean_queue_ms").and_then(|j| j.as_f64()).unwrap_or(0.0),
            mean_compute_ms: v.get("mean_compute_ms").and_then(|j| j.as_f64()).unwrap_or(0.0),
            throughput_img_s: v.req_f64("throughput_img_s")?,
            sim_energy_uj: v.req_f64("sim_energy_uj")?,
            plan_hits: v.req_f64("plan_hits")? as u64,
            plan_misses: v.req_f64("plan_misses")? as u64,
            plan_compile_ms: v.req_f64("plan_compile_ms")?,
            makespan_ms: v.req_f64("makespan_ms")?,
            faults_injected: v.req_f64("faults_injected")? as u64,
            batch_aborts: v.req_f64("batch_aborts")? as u64,
            retries: v.req_f64("retries")? as u64,
            shed_requests: v.req_f64("shed_requests")? as u64,
            failed_requests: v.req_f64("failed_requests")? as u64,
            degraded_requests: v.req_f64("degraded_requests")? as usize,
            degraded_p95_ms: v.req_f64("degraded_p95_ms")?,
        })
    }
}

/// Persist a report atomically under the versioned envelope.
pub fn save_report(path: &Path, report: &ServeReport) -> Result<()> {
    store::save_versioned(path, "serve_report", SERVE_SCHEMA, report.to_json())
}

/// Load a persisted report (clear error on kind/schema mismatch).
pub fn load_report(path: &Path) -> Result<ServeReport> {
    ServeReport::from_json(&store::load_versioned(path, "serve_report", SERVE_SCHEMA)?)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn outcome(point: usize, queue: u64, compute: u64, met: bool) -> RequestOutcome {
        RequestOutcome {
            id: 0,
            model: 0,
            point,
            queue_cycles: queue,
            compute_cycles: compute,
            sla_met: met,
            batch_size: 2,
            energy_uj: 1.5,
            degraded: false,
            tenant: Tenant::Interactive,
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 50), 50);
        assert_eq!(percentile(&v, 95), 95);
        assert_eq!(percentile(&v, 100), 100);
        assert_eq!(percentile(&[7], 95), 7);
        assert_eq!(percentile(&[], 50), 0);
    }

    #[test]
    fn tenant_from_sla() {
        assert_eq!(Tenant::from_sla(&Sla::MinEnergy), Tenant::Batch);
        assert_eq!(Tenant::from_sla(&Sla::LatencyBudget(1_000)), Tenant::Interactive);
        assert_eq!(Tenant::Interactive.name(), "interactive");
        assert_eq!(Tenant::Batch.name(), "batch");
    }

    #[test]
    fn report_aggregates_per_point() {
        let mut m = ServeMetrics::new();
        m.record(outcome(0, 10, 100, true));
        m.record(outcome(0, 30, 100, false));
        m.record(outcome(1, 0, 50, true));
        m.record_batch(1_000_000);
        m.registry_mut().set(crate::obs::ctr::END_CYCLE, 500);
        let labels = vec!["a".to_string(), "b".to_string()];
        let rep = m.report("tinycnn", "diana", 2, &labels, 1e6);
        assert_eq!(rep.rows.len(), 2);
        assert_eq!(rep.rows[0].requests, 2);
        assert_eq!(rep.rows[0].sla_hits, 1);
        assert_eq!(rep.rows[1].requests, 1);
        assert_eq!(rep.total_requests, 3);
        assert!((rep.sla_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        // at 1 MHz, 110 cycles = 0.11 ms is the run-level median
        assert!((rep.p50_ms - 0.11).abs() < 1e-9, "p50 {}", rep.p50_ms);
        let dash = rep.dashboard();
        assert!(dash.contains("| a |") && dash.contains("| b |"), "{dash}");
    }

    #[test]
    fn tenant_rows_partition_requests() {
        let mut m = ServeMetrics::new();
        m.record(outcome(0, 10, 100, true));
        m.record(RequestOutcome { tenant: Tenant::Batch, ..outcome(0, 30, 200, true) });
        m.record(outcome(0, 50, 100, false));
        m.registry_mut().inc(crate::obs::ctr::SHED);
        m.registry_mut().inc(crate::obs::ctr::SHED_INTERACTIVE);
        let rep = m.report("tinycnn", "diana", 1, &["a".to_string()], 1e6);
        assert_eq!(rep.tenant_rows.len(), 2);
        let inter = &rep.tenant_rows[0];
        assert_eq!(inter.tenant, "interactive");
        assert_eq!(inter.requests, 2);
        assert_eq!(inter.sla_hits, 1);
        assert_eq!(inter.shed, 1);
        let batch = &rep.tenant_rows[1];
        assert_eq!(batch.tenant, "batch");
        assert_eq!(batch.requests, 1);
        assert_eq!(batch.shed, 0);
        // the batch tenant's only request: 230 cycles = 0.23 ms
        assert!((batch.p95_ms - 0.23).abs() < 1e-9, "{}", batch.p95_ms);
        let sum: usize = rep.tenant_rows.iter().map(|t| t.requests).sum();
        assert_eq!(sum, rep.total_requests, "tenants partition the served requests");
        let dash = rep.dashboard();
        assert!(dash.contains("| interactive | 2 | 1 |"), "{dash}");
        assert!(dash.contains("| batch | 1 | 0 |"), "{dash}");
    }

    #[test]
    fn multi_model_report_prefixes_rows_and_partitions_models() {
        let mut m = ServeMetrics::new();
        m.record(outcome(0, 10, 100, true));
        m.record(RequestOutcome { model: 1, ..outcome(0, 20, 300, true) });
        m.record(RequestOutcome { model: 1, ..outcome(1, 40, 300, false) });
        let models = vec![
            ("alpha".to_string(), vec!["a0".to_string()]),
            ("beta".to_string(), vec!["b0".to_string(), "b1".to_string()]),
        ];
        let rep = m.report_multi(&models, "diana", 2, 1e6);
        assert_eq!(rep.model, "alpha+beta");
        // point 0 exists in both models: the rows must not merge
        let labels: Vec<&str> = rep.rows.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["alpha:a0", "beta:b0", "beta:b1"]);
        assert_eq!(rep.rows[0].requests, 1);
        assert_eq!(rep.model_rows.len(), 2);
        assert_eq!(rep.model_rows[0].model, "alpha");
        assert_eq!(rep.model_rows[0].requests, 1);
        assert_eq!(rep.model_rows[1].model, "beta");
        assert_eq!(rep.model_rows[1].requests, 2);
        assert_eq!(rep.model_rows[1].sla_hits, 1);
        let sum: usize = rep.model_rows.iter().map(|r| r.requests).sum();
        assert_eq!(sum, rep.total_requests, "models partition the served requests");
        let dash = rep.dashboard();
        assert!(dash.contains("| alpha | 1 |"), "{dash}");
        assert!(dash.contains("| beta | 2 |"), "{dash}");
        // single-model reports keep unprefixed labels and one model row
        let single = m.report("alpha", "diana", 2, &["a0".to_string(), "a1".to_string()], 1e6);
        assert!(single.rows.iter().all(|r| !r.label.contains(':')), "no prefix");
        assert_eq!(single.model_rows.len(), 1);
    }

    #[test]
    fn report_json_roundtrip() {
        let mut m = ServeMetrics::new();
        m.record(outcome(0, 5, 20, true));
        m.record_batch(2_000);
        {
            let g = m.registry_mut();
            g.set(crate::obs::ctr::PLAN_HITS, 3);
            g.set(crate::obs::ctr::PLAN_MISSES, 1);
            g.set(crate::obs::ctr::PLAN_COMPILE_NS, 4_000_000);
            g.set(crate::obs::ctr::END_CYCLE, 25);
        }
        let rep = m.report("tinycnn", "mpsoc4", 4, &["x".to_string()], 5e8);
        let dir = std::env::temp_dir().join("odimo_serve_report");
        let path = dir.join("report.json");
        save_report(&path, &rep).unwrap();
        let back = load_report(&path).unwrap();
        assert_eq!(back.model, "tinycnn");
        assert_eq!(back.platform, "mpsoc4");
        assert_eq!(back.rows.len(), 1);
        assert_eq!(back.rows[0].label, "x");
        assert_eq!(back.plan_hits, 3);
        assert!((back.p95_ms - rep.p95_ms).abs() < 1e-12);
        // v2.1 latency split survives the roundtrip
        assert!(rep.mean_queue_ms > 0.0 && rep.mean_compute_ms > 0.0);
        assert!((back.mean_queue_ms - rep.mean_queue_ms).abs() < 1e-12);
        assert!((back.mean_compute_ms - rep.mean_compute_ms).abs() < 1e-12);
        // v2.2 tenant rows survive the roundtrip
        assert_eq!(back.tenant_rows.len(), rep.tenant_rows.len());
        assert_eq!(back.tenant_rows[0].tenant, "interactive");
        assert_eq!(back.tenant_rows[0].requests, 1);
        assert_eq!(back.dashboard(), rep.dashboard());
        assert_eq!(back.deterministic_digest(), rep.deterministic_digest());
    }

    #[test]
    fn fault_counters_flow_into_report_and_digest() {
        let mut m = ServeMetrics::new();
        m.record(outcome(0, 10, 100, true));
        m.record(RequestOutcome { degraded: true, ..outcome(0, 400, 100, false) });
        m.record_batch(1_000);
        {
            let g = m.registry_mut();
            g.set(crate::obs::ctr::FAULTS_INJECTED, 2);
            g.set(crate::obs::ctr::BATCH_ABORTS, 1);
            g.set(crate::obs::ctr::RETRIES, 3);
            g.set(crate::obs::ctr::SHED, 4);
            g.set(crate::obs::ctr::FAILED, 1);
            g.set(crate::obs::ctr::END_CYCLE, 900);
        }
        let rep = m.report("tinycnn", "mpsoc4", 2, &["a".to_string()], 1e6);
        assert_eq!(rep.faults_injected, 2);
        assert_eq!(rep.batch_aborts, 1);
        assert_eq!(rep.retries, 3);
        assert_eq!(rep.shed_requests, 4);
        assert_eq!(rep.failed_requests, 1);
        assert_eq!(rep.degraded_requests, 1);
        // one degraded request: its own latency is the degraded p95
        assert!((rep.degraded_p95_ms - 0.5).abs() < 1e-9, "{}", rep.degraded_p95_ms);
        assert_eq!(rep.accounted(), 2 + 4 + 1);
        let dash = rep.dashboard();
        assert!(
            dash.contains("faults: 2 injected | 1 batch aborts | 3 retries | 4 shed | 1 failed"),
            "{dash}"
        );
        // the digest tracks fault accounting but not wall-clock fields
        let mut other = rep.clone();
        other.throughput_img_s += 123.0;
        other.plan_compile_ms += 9.0;
        other.threads = 8;
        // v2.1 split fields are derived, not digested
        other.mean_queue_ms += 1.0;
        other.mean_compute_ms += 1.0;
        // v2.2 tenant rows / v2.3 model rows are derived, not digested
        other.tenant_rows.clear();
        other.model_rows.clear();
        assert_eq!(other.deterministic_digest(), rep.deterministic_digest());
        other.shed_requests += 1;
        assert_ne!(other.deterministic_digest(), rep.deterministic_digest());
    }

    #[test]
    fn zero_fault_report_prints_zero_fault_line() {
        let mut m = ServeMetrics::new();
        m.record(outcome(0, 1, 2, true));
        let rep = m.report("tinycnn", "diana", 1, &["a".to_string()], 1e6);
        assert!(
            rep.dashboard().contains("faults: 0 injected | 0 batch aborts"),
            "fault line must always be printed so dashboards diff cleanly"
        );
        assert_eq!(rep.degraded_requests, 0);
        assert_eq!(rep.degraded_p95_ms, 0.0);
    }
}
