//! Replayable JSONL request traces — the canonical serve load source.
//!
//! A trace is a sequence of [`TraceRecord`]s, one JSON object per line:
//!
//! ```text
//! {"arrival_cycle":"21345","sla":{"latency_budget":"800000"},"tenant":"interactive","model":"tinycnn","seed":"1234"}
//! {"arrival_cycle":"40190","sla":"min_energy","tenant":"batch","model":"tinycnn","seed":"1234"}
//! ```
//!
//! Every u64 (arrival cycle, latency budget, seed) travels as a
//! *decimal string*, never a JSON number: JSON numbers are f64 and
//! silently lose precision above 2^53 — the same hazard the seed cache
//! fixed in the frontier store. Arrivals must be non-decreasing;
//! `tenant` is a free-form label restricted to `[a-z0-9_-]+` (so
//! emission never needs escaping); `model` must name a bundled model.
//! Malformed input surfaces as a typed [`TraceError`], never a panic.
//!
//! [`Trace::synth`] is the old synthetic generator re-homed as one
//! trace *producer*: it replays the exact `Pcg32(seed, 101)` stream the
//! serve loop has always used, so a synthesized trace replays
//! byte-identical to the historical in-memory request stream.

use std::fmt;
use std::fs;
use std::path::Path;

use crate::model::ALL_MODELS;
use crate::util::json::{self, Json};
use crate::util::prng::Pcg32;

use super::batcher::Request;
use super::dispatch::Sla;
use super::sweep::FrontierPoint;
use super::ServeOpts;

/// Typed trace-format failures. Each parse-side variant carries the
/// 1-based line number, so a bad record in a million-line trace is
/// addressable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceError {
    /// Reading or writing the trace file failed.
    Io {
        /// Path the operation targeted.
        path: String,
        /// Underlying I/O error text.
        msg: String,
    },
    /// A line is not a well-formed JSON object.
    Parse {
        /// 1-based line number.
        line: usize,
        /// Parser diagnostic.
        msg: String,
    },
    /// A required field is absent.
    MissingField {
        /// 1-based line number.
        line: usize,
        /// The missing field.
        field: &'static str,
    },
    /// A u64 field is not a decimal string (JSON numbers are rejected:
    /// they are f64 and corrupt values above 2^53).
    BadNumber {
        /// 1-based line number.
        line: usize,
        /// The offending field.
        field: &'static str,
        /// What the line actually contained.
        value: String,
    },
    /// Arrival cycles went backwards between consecutive records.
    OutOfOrder {
        /// 1-based line number of the offending record.
        line: usize,
        /// Previous record's arrival cycle.
        prev: u64,
        /// This record's (earlier) arrival cycle.
        got: u64,
    },
    /// Tenant label violates the `[a-z0-9_-]+` charset.
    BadTenant {
        /// 1-based line number.
        line: usize,
        /// The offending label.
        tenant: String,
    },
    /// Model is not one of the bundled models.
    UnknownModel {
        /// 1-based line number.
        line: usize,
        /// The offending model name.
        model: String,
    },
    /// The `sla` field is neither `"min_energy"` nor
    /// `{"latency_budget": "<cycles>"}`.
    BadSla {
        /// 1-based line number.
        line: usize,
        /// What was wrong with it.
        msg: String,
    },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Io { path, msg } => write!(f, "trace: io error on {path}: {msg}"),
            TraceError::Parse { line, msg } => {
                write!(f, "trace line {line}: not a json object ({msg})")
            }
            TraceError::MissingField { line, field } => {
                write!(f, "trace line {line}: missing field '{field}'")
            }
            TraceError::BadNumber { line, field, value } => write!(
                f,
                "trace line {line}: field '{field}' must be a u64 decimal string \
                 (json numbers are f64 and corrupt cycles above 2^53), got {value}"
            ),
            TraceError::OutOfOrder { line, prev, got } => write!(
                f,
                "trace line {line}: arrival_cycle {got} is earlier than the previous \
                 record's {prev} — traces must be sorted by arrival"
            ),
            TraceError::BadTenant { line, tenant } => write!(
                f,
                "trace line {line}: tenant '{tenant}' must be non-empty [a-z0-9_-]+"
            ),
            TraceError::UnknownModel { line, model } => write!(
                f,
                "trace line {line}: unknown model '{model}' (choose from {ALL_MODELS:?})"
            ),
            TraceError::BadSla { line, msg } => write!(f, "trace line {line}: bad sla: {msg}"),
        }
    }
}

impl std::error::Error for TraceError {}

/// One request in a trace (one JSONL line).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    /// Arrival time on the shared virtual timeline, simulated cycles.
    pub arrival_cycle: u64,
    /// The request's SLA.
    pub sla: Sla,
    /// Tenant label (`[a-z0-9_-]+`), carried into per-tenant dashboards.
    pub tenant: String,
    /// Model the request targets (must match the serving session's).
    pub model: String,
    /// Per-request input seed (drives `gen_sample` for this request).
    pub seed: u64,
}

/// A replayable request trace: records sorted by arrival cycle.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Trace {
    /// The records, in non-decreasing `arrival_cycle` order.
    pub records: Vec<TraceRecord>,
}

fn valid_label(s: &str) -> bool {
    !s.is_empty()
        && s.bytes().all(|c| {
            c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'_' || c == b'-'
        })
}

/// Required u64 field, transported as a decimal string.
fn u64_field(v: &Json, line: usize, field: &'static str) -> Result<u64, TraceError> {
    let node = v.get(field).ok_or(TraceError::MissingField { line, field })?;
    let s = node
        .as_str()
        .ok_or_else(|| TraceError::BadNumber { line, field, value: node.to_string() })?;
    s.parse::<u64>()
        .map_err(|_| TraceError::BadNumber { line, field, value: format!("\"{s}\"") })
}

fn str_field<'a>(v: &'a Json, line: usize, field: &'static str) -> Result<&'a str, TraceError> {
    let node = v.get(field).ok_or(TraceError::MissingField { line, field })?;
    node.as_str().ok_or(TraceError::MissingField { line, field })
}

fn sla_from_json(v: &Json, line: usize) -> Result<Sla, TraceError> {
    let node = v.get("sla").ok_or(TraceError::MissingField { line, field: "sla" })?;
    match node {
        Json::Str(s) if s == "min_energy" => Ok(Sla::MinEnergy),
        Json::Obj(_) => {
            if node.get("latency_budget").is_none() {
                return Err(TraceError::BadSla {
                    line,
                    msg: "object form must be {\"latency_budget\": \"<cycles>\"}".to_string(),
                });
            }
            let b = u64_field(node, line, "latency_budget")?;
            Ok(Sla::LatencyBudget(b))
        }
        other => Err(TraceError::BadSla {
            line,
            msg: format!(
                "expected \"min_energy\" or {{\"latency_budget\": \"<cycles>\"}}, got {other}"
            ),
        }),
    }
}

impl TraceRecord {
    /// One JSONL line (no trailing newline). Labels are charset-checked
    /// at construction/parse time, so no JSON escaping is ever needed.
    fn to_line(&self) -> String {
        let sla = match self.sla {
            Sla::MinEnergy => "\"min_energy\"".to_string(),
            Sla::LatencyBudget(b) => format!("{{\"latency_budget\":\"{b}\"}}"),
        };
        format!(
            "{{\"arrival_cycle\":\"{}\",\"sla\":{},\"tenant\":\"{}\",\"model\":\"{}\",\"seed\":\"{}\"}}",
            self.arrival_cycle, sla, self.tenant, self.model, self.seed
        )
    }

    fn from_line(
        line_no: usize,
        text: &str,
        known_models: &[&str],
    ) -> Result<TraceRecord, TraceError> {
        let v = json::parse(text)
            .map_err(|e| TraceError::Parse { line: line_no, msg: e.to_string() })?;
        if v.as_obj().is_none() {
            return Err(TraceError::Parse {
                line: line_no,
                msg: "expected a json object".to_string(),
            });
        }
        let arrival_cycle = u64_field(&v, line_no, "arrival_cycle")?;
        let sla = sla_from_json(&v, line_no)?;
        let tenant = str_field(&v, line_no, "tenant")?.to_string();
        if !valid_label(&tenant) {
            return Err(TraceError::BadTenant { line: line_no, tenant });
        }
        let model = str_field(&v, line_no, "model")?.to_string();
        if !known_models.contains(&model.as_str()) {
            return Err(TraceError::UnknownModel { line: line_no, model });
        }
        let seed = u64_field(&v, line_no, "seed")?;
        Ok(TraceRecord { arrival_cycle, sla, tenant, model, seed })
    }
}

impl Trace {
    /// Records in the trace.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when the trace has no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Parse a full JSONL document (blank lines ignored). Enforces the
    /// sorted-arrival invariant across records; models are checked
    /// against the bundled [`ALL_MODELS`] set.
    pub fn from_jsonl_text(text: &str) -> Result<Trace, TraceError> {
        Trace::from_jsonl_text_known(text, &ALL_MODELS)
    }

    /// Like [`Trace::from_jsonl_text`] but validating each record's
    /// `model` against a caller-supplied set — the serving session's
    /// own models (which may be imported graphs outside
    /// [`ALL_MODELS`]). [`TraceError::UnknownModel`] carries the
    /// *physical* 1-based line number (blank lines count), so the bad
    /// record in a million-line trace is addressable in an editor.
    pub fn from_jsonl_text_known(
        text: &str,
        known_models: &[&str],
    ) -> Result<Trace, TraceError> {
        let mut records = Vec::new();
        let mut prev: Option<u64> = None;
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            if raw.trim().is_empty() {
                continue;
            }
            let rec = TraceRecord::from_line(line_no, raw, known_models)?;
            if let Some(p) = prev {
                if rec.arrival_cycle < p {
                    return Err(TraceError::OutOfOrder {
                        line: line_no,
                        prev: p,
                        got: rec.arrival_cycle,
                    });
                }
            }
            prev = Some(rec.arrival_cycle);
            records.push(rec);
        }
        Ok(Trace { records })
    }

    /// Emit the full JSONL document (one record per line, trailing
    /// newline when non-empty).
    pub fn to_jsonl_text(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_line());
            out.push('\n');
        }
        out
    }

    /// Load a trace from a JSONL file (models checked against
    /// [`ALL_MODELS`]).
    pub fn load(path: &Path) -> Result<Trace, TraceError> {
        let text = fs::read_to_string(path).map_err(|e| TraceError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        Trace::from_jsonl_text(&text)
    }

    /// Load a trace, validating models against the serving set (see
    /// [`Trace::from_jsonl_text_known`]).
    pub fn load_known(path: &Path, known_models: &[&str]) -> Result<Trace, TraceError> {
        let text = fs::read_to_string(path).map_err(|e| TraceError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        Trace::from_jsonl_text_known(&text, known_models)
    }

    /// Save the trace as a JSONL file (atomic via tempfile-rename).
    pub fn save(&self, path: &Path) -> Result<(), TraceError> {
        crate::exp::store::write_atomic(path, &self.to_jsonl_text()).map_err(|e| {
            TraceError::Io { path: path.display().to_string(), msg: e.to_string() }
        })
    }

    /// The historical synthetic generator, now a trace producer: mean
    /// inter-arrival gap `opts.mean_gap`, ~15% min-energy SLAs, the
    /// rest latency budgets drawn around the frontier's own latency
    /// range (so some are infeasible by construction and exercise the
    /// fallback path). The `Pcg32::new(seed, 101)` draw sequence is
    /// byte-identical to the pre-trace `synth_requests`; tenants derive
    /// from the SLA (no extra draws) and every record carries the
    /// session seed, so replay regenerates the same inputs.
    pub fn synth(
        opts: &ServeOpts,
        n_requests: usize,
        seed: u64,
        frontier: &[FrontierPoint],
        model: &str,
    ) -> Trace {
        let min_cyc = frontier.iter().map(|p| p.cycles).min().unwrap_or(0);
        let max_cyc = frontier.iter().map(|p| p.cycles).max().unwrap_or(0);
        let lo = (min_cyc as f64 * 0.8) as u64;
        let hi = (max_cyc + opts.launch_cycles) as f64 * 1.6;
        let mut rng = Pcg32::new(seed, 101);
        let mut t = 0u64;
        let mut records = Vec::with_capacity(n_requests);
        for _ in 0..n_requests {
            t += 1 + (rng.next_f32() as f64 * 2.0 * opts.mean_gap as f64) as u64;
            let sla = if rng.next_f32() < 0.15 {
                Sla::MinEnergy
            } else {
                let u = rng.next_f32() as f64;
                Sla::LatencyBudget(lo + (u * (hi - lo as f64).max(1.0)) as u64)
            };
            let tenant = match sla {
                Sla::MinEnergy => "batch",
                Sla::LatencyBudget(_) => "interactive",
            };
            records.push(TraceRecord {
                arrival_cycle: t,
                sla,
                tenant: tenant.to_string(),
                model: model.to_string(),
                seed,
            });
        }
        Trace { records }
    }

    /// Materialize driver requests: ids are record indices (they double
    /// as the synthetic-input sample index), `point` is a placeholder
    /// until dispatch, `model` is 0 (the single-model plane).
    pub fn to_requests(&self) -> Vec<Request> {
        self.records
            .iter()
            .enumerate()
            .map(|(i, rec)| Request {
                id: i as u64,
                arrival: rec.arrival_cycle,
                sla: rec.sla,
                model: 0,
                point: 0,
            })
            .collect()
    }

    /// Like [`Trace::to_requests`] but routing each record to its
    /// model's index in `models`. Records must already have been
    /// validated against this set ([`Trace::from_jsonl_text_known`]);
    /// a record naming a model outside it is an `Err` carrying the
    /// offending record index.
    pub fn to_requests_routed(&self, models: &[String]) -> Result<Vec<Request>, usize> {
        self.records
            .iter()
            .enumerate()
            .map(|(i, rec)| {
                let model = models
                    .iter()
                    .position(|m| *m == rec.model)
                    .ok_or(i)? as u32;
                Ok(Request {
                    id: i as u64,
                    arrival: rec.arrival_cycle,
                    sla: rec.sla,
                    model,
                    point: 0,
                })
            })
            .collect()
    }

    /// Per-record input seeds, indexed like [`Trace::to_requests`] ids.
    pub fn seeds(&self) -> Vec<u64> {
        self.records.iter().map(|r| r.seed).collect()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn rec(t: u64, sla: Sla) -> TraceRecord {
        TraceRecord {
            arrival_cycle: t,
            sla,
            tenant: "interactive".to_string(),
            model: "tinycnn".to_string(),
            seed: 42,
        }
    }

    #[test]
    fn roundtrip_preserves_records() {
        let tr = Trace {
            records: vec![
                rec(10, Sla::LatencyBudget(800_000)),
                rec(20, Sla::MinEnergy),
                rec(20, Sla::LatencyBudget(u64::MAX)),
            ],
        };
        let text = tr.to_jsonl_text();
        let back = Trace::from_jsonl_text(&text).unwrap();
        assert_eq!(tr, back);
    }

    #[test]
    fn u64_above_f64_precision_survives() {
        // 2^53 + 1 is unrepresentable as f64; the decimal-string
        // transport must carry it exactly
        let big = (1u64 << 53) + 1;
        let mut r = rec(big, Sla::LatencyBudget(big));
        r.seed = u64::MAX;
        let tr = Trace { records: vec![r] };
        let back = Trace::from_jsonl_text(&tr.to_jsonl_text()).unwrap();
        assert_eq!(back.records[0].arrival_cycle, big);
        assert_eq!(back.records[0].sla, Sla::LatencyBudget(big));
        assert_eq!(back.records[0].seed, u64::MAX);
    }

    #[test]
    fn numeric_cycle_field_is_a_typed_error() {
        let line = r#"{"arrival_cycle":9007199254740993,"sla":"min_energy","tenant":"t","model":"tinycnn","seed":"1"}"#;
        match Trace::from_jsonl_text(line) {
            Err(TraceError::BadNumber { line: 1, field: "arrival_cycle", .. }) => {}
            other => panic!("expected BadNumber, got {other:?}"),
        }
    }

    #[test]
    fn out_of_order_arrivals_rejected() {
        let tr = Trace {
            records: vec![rec(100, Sla::MinEnergy), rec(99, Sla::MinEnergy)],
        };
        match Trace::from_jsonl_text(&tr.to_jsonl_text()) {
            Err(TraceError::OutOfOrder { line: 2, prev: 100, got: 99 }) => {}
            other => panic!("expected OutOfOrder, got {other:?}"),
        }
    }

    #[test]
    fn synth_matches_request_stream_shape() {
        let opts = ServeOpts::default();
        let tr = Trace::synth(&opts, 16, 7, &[], "tinycnn");
        assert_eq!(tr.len(), 16);
        let reqs = tr.to_requests();
        assert_eq!(reqs.len(), 16);
        for (i, (r, rc)) in reqs.iter().zip(&tr.records).enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.arrival, rc.arrival_cycle);
            assert_eq!(r.sla, rc.sla);
            let want = match rc.sla {
                Sla::MinEnergy => "batch",
                Sla::LatencyBudget(_) => "interactive",
            };
            assert_eq!(rc.tenant, want);
            assert_eq!(rc.seed, 7);
        }
        // arrivals strictly increase (gap >= 1 per step)
        for w in reqs.windows(2) {
            assert!(w[0].arrival < w[1].arrival);
        }
    }
}
