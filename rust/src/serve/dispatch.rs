//! Deadline-aware dispatcher: map one request's SLA onto a frontier
//! point.
//!
//! SLA semantics (documented in EXPERIMENTS.md §Serve):
//!
//!   * [`Sla::LatencyBudget`] — the request carries a latency budget in
//!     simulated cycles. The dispatcher picks the *cheapest* (lowest
//!     simulated energy) frontier mapping whose per-inference compute
//!     latency fits the budget; ties prefer the faster mapping, then
//!     the earlier frontier index. If no frontier point fits, it falls
//!     back to the *fastest* mapping and flags the SLA miss.
//!   * [`Sla::MinEnergy`] — no deadline; the globally cheapest mapping
//!     wins (ties to the faster one).
//!
//! Dispatch is a pure function of (frontier, SLA): per-request mapping
//! choices are fully deterministic, which is what makes serve runs
//! reproducible end to end.

use super::sweep::FrontierPoint;

/// One request's service-level agreement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sla {
    /// Finish within this many simulated cycles (compute-latency bound
    /// at planning time; end-to-end accounting happens in metrics).
    LatencyBudget(u64),
    /// No deadline — minimize simulated energy.
    MinEnergy,
}

/// A dispatch outcome: the chosen frontier index, and whether the
/// choice satisfies the SLA at planning time (`false` means the
/// fastest-mapping fallback was taken and the budget is already
/// infeasible before any queueing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    /// Index into the frontier passed to [`dispatch`].
    pub point: usize,
    /// Planning-time SLA feasibility.
    pub sla_met: bool,
}

/// Index of the cheapest point (min energy, ties to lower latency then
/// lower index) among `idx`; `None` when `idx` is empty.
fn cheapest(frontier: &[FrontierPoint], idx: impl Iterator<Item = usize>) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in idx {
        best = Some(match best {
            None => i,
            Some(b) => {
                let (pb, pi) = (&frontier[b], &frontier[i]);
                if pi.energy_uj < pb.energy_uj
                    || (pi.energy_uj == pb.energy_uj && pi.cycles < pb.cycles)
                {
                    i
                } else {
                    b
                }
            }
        });
    }
    best
}

/// Index of the fastest point (min cycles, ties to lower energy then
/// lower index) among `idx`; `None` when `idx` is empty.
fn fastest(frontier: &[FrontierPoint], idx: impl Iterator<Item = usize>) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in idx {
        best = Some(match best {
            None => i,
            Some(b) => {
                let (pb, pi) = (&frontier[b], &frontier[i]);
                if pi.cycles < pb.cycles
                    || (pi.cycles == pb.cycles && pi.energy_uj < pb.energy_uj)
                {
                    i
                } else {
                    b
                }
            }
        });
    }
    best
}

/// Select the frontier mapping for one SLA (module docs give the full
/// semantics). Returns `None` only on an empty frontier.
pub fn dispatch(frontier: &[FrontierPoint], sla: Sla) -> Option<Decision> {
    dispatch_filtered(frontier, |_| true, sla)
}

/// [`dispatch`] restricted to the points `keep` admits — the
/// fault-aware form: the serve loop passes the health tracker's
/// enabled mask so dead-unit mappings are never selected. Selection
/// among the kept points follows the exact [`dispatch`] semantics.
/// Returns `None` when `keep` admits no point at all (every unit a
/// mapping needs is down) — the caller decides whether to defer or
/// fail, never this function.
pub fn dispatch_filtered(
    frontier: &[FrontierPoint],
    keep: impl Fn(usize) -> bool,
    sla: Sla,
) -> Option<Decision> {
    let kept = || (0..frontier.len()).filter(|&i| keep(i));
    match sla {
        Sla::MinEnergy => {
            cheapest(frontier, kept()).map(|i| Decision { point: i, sla_met: true })
        }
        Sla::LatencyBudget(budget) => {
            let feasible = kept().filter(|&i| frontier[i].cycles <= budget);
            if let Some(i) = cheapest(frontier, feasible) {
                return Some(Decision { point: i, sla_met: true });
            }
            fastest(frontier, kept()).map(|i| Decision { point: i, sla_met: false })
        }
    }
}

/// Index of the fastest kept point (the admission controller's
/// degraded-service target); `None` when `keep` admits nothing.
pub fn fastest_filtered(frontier: &[FrontierPoint], keep: impl Fn(usize) -> bool) -> Option<usize> {
    fastest(frontier, (0..frontier.len()).filter(|&i| keep(i)))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::Mapping;
    use std::collections::BTreeMap;

    fn pt(cycles: u64, energy_uj: f64) -> FrontierPoint {
        FrontierPoint {
            label: format!("{cycles}c"),
            mapping: Mapping { assign: BTreeMap::new() },
            cycles,
            latency_ms: cycles as f64 * 1e-6,
            energy_uj,
            acc_proxy: 0.5,
        }
    }

    #[test]
    fn budget_picks_cheapest_feasible() {
        let f = vec![pt(100, 9.0), pt(200, 4.0), pt(400, 2.0)];
        let d = dispatch(&f, Sla::LatencyBudget(250)).unwrap();
        assert_eq!(d.point, 1, "cheapest among the two feasible points");
        assert!(d.sla_met);
    }

    #[test]
    fn infeasible_budget_falls_back_to_fastest() {
        let f = vec![pt(100, 9.0), pt(200, 4.0)];
        let d = dispatch(&f, Sla::LatencyBudget(50)).unwrap();
        assert_eq!(d.point, 0, "fastest mapping under an infeasible budget");
        assert!(!d.sla_met, "the miss must be flagged");
    }

    #[test]
    fn min_energy_ignores_latency() {
        let f = vec![pt(100, 9.0), pt(400, 2.0)];
        let d = dispatch(&f, Sla::MinEnergy).unwrap();
        assert_eq!(d.point, 1);
        assert!(d.sla_met);
    }

    #[test]
    fn energy_ties_prefer_faster_then_earlier() {
        let f = vec![pt(300, 4.0), pt(200, 4.0), pt(200, 4.0)];
        let d = dispatch(&f, Sla::MinEnergy).unwrap();
        assert_eq!(d.point, 1, "tie broken to lower latency, then lower index");
    }

    #[test]
    fn empty_frontier_is_none() {
        assert_eq!(dispatch(&[], Sla::MinEnergy), None);
        assert_eq!(dispatch(&[], Sla::LatencyBudget(1)), None);
    }

    #[test]
    fn filtered_dispatch_respects_the_mask() {
        let f = vec![pt(100, 9.0), pt(200, 4.0), pt(400, 2.0)];
        let mask = [true, false, true];
        // the cheapest feasible point is masked out: next-best wins
        let d = dispatch_filtered(&f, |i| mask[i], Sla::LatencyBudget(250)).unwrap();
        assert_eq!(d.point, 0, "point 1 is masked; 0 is the only feasible survivor");
        assert!(d.sla_met);
        let d = dispatch_filtered(&f, |i| mask[i], Sla::MinEnergy).unwrap();
        assert_eq!(d.point, 2);
        // fallback also honors the mask
        let d = dispatch_filtered(&f, |i| mask[i], Sla::LatencyBudget(50)).unwrap();
        assert_eq!(d.point, 0, "fastest surviving point");
        assert!(!d.sla_met);
        // an all-false mask dispatches nothing
        assert_eq!(dispatch_filtered(&f, |_| false, Sla::MinEnergy), None);
        assert_eq!(fastest_filtered(&f, |_| false), None);
        assert_eq!(fastest_filtered(&f, |i| mask[i]), Some(0));
        // the unmasked form is exactly dispatch()
        for sla in [Sla::MinEnergy, Sla::LatencyBudget(250), Sla::LatencyBudget(50)] {
            assert_eq!(dispatch(&f, sla), dispatch_filtered(&f, |_| true, sla));
        }
    }
}
