//! Serve-time degradation layer: unit-health tracking, per-fault-state
//! degraded re-mapping, and admission control.
//!
//! The [`HealthTracker`] sits between the fault timeline
//! ([`crate::hw::faults`]) and the closed-loop driver (`mod.rs`). It
//! owns a *growing* frontier point set: the original swept points keep
//! their indices forever (so batches in flight never see an index
//! shift), and degraded re-map points are appended behind them. An
//! `enabled` mask — recomputed whenever the fault state changes —
//! decides what the dispatcher may pick *right now*:
//!
//!   * an original point is enabled iff none of the units its mapping
//!     assigns channels to (plus the depthwise unit, when the graph has
//!     depthwise layers) is down;
//!   * when a fault state disables at least one original point, the
//!     tracker re-runs water-filling `min_cost` (latency and energy
//!     objectives) on the [`Platform::degraded`] view, scores the
//!     resulting mappings on the simulator, and appends them as
//!     `deg[...]` points enabled only under that exact fault state.
//!     Re-mapping is cached per [`FaultState::key`], so a transient
//!     outage that recurs reuses its points (and their compiled plans).
//!
//! Derated (but up) units do not trigger re-mapping: their original
//! points stay enabled and the driver stretches execution by the
//! tracker's [`HealthTracker::exec_factor`] at run time — a
//! conservative whole-pipeline approximation documented in
//! ARCHITECTURE.md §Faults. Degraded re-map points are scored on the
//! already-derated platform view, so the factor is never applied twice.
//!
//! [`AdmissionCfg`] is the overload policy: an arrival whose projected
//! device wait exceeds `overload_wait` is shed when it has no deadline
//! (min-energy requests are the lowest priority) and degraded to the
//! fastest healthy mapping when it has one it could still meet —
//! predictable degradation instead of an unbounded queue.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::baselines::{min_cost, CostObjective};
use crate::coordinator::Mapping;
use crate::hw::faults::{FaultState, ResolvedFaults};
use crate::hw::soc::{simulate, SocConfig};
use crate::hw::Platform;
use crate::model::{Graph, Op};

use super::sweep::FrontierPoint;

/// Overload admission policy for [`ServeOpts`](super::ServeOpts).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionCfg {
    /// Projected device wait (cycles the device backlog is ahead of an
    /// arrival) beyond which the arrival is shed (min-energy SLA) or
    /// degraded to the fastest healthy mapping (latency SLA that the
    /// fastest mapping could still meet; otherwise shed). The default
    /// `u64::MAX` never sheds — byte-identical to pre-fault serving.
    pub overload_wait: u64,
}

impl Default for AdmissionCfg {
    fn default() -> Self {
        AdmissionCfg { overload_wait: u64::MAX }
    }
}

/// Cached degraded re-mapping for one fault state.
struct DegradedCtx {
    /// The degraded platform view (spec-hash distinct, see
    /// [`Platform::degraded`]).
    platform: Platform,
    /// Indices (into the tracker's point set) of this state's re-map
    /// points.
    point_idx: Vec<usize>,
}

/// Unit-health tracker + growing point set (module docs).
pub(crate) struct HealthTracker {
    resolved: Option<ResolvedFaults>,
    base: Platform,
    state: FaultState,
    state_key: u64,
    /// Original frontier points followed by appended re-map points;
    /// indices are stable for the lifetime of a run.
    pub points: Vec<FrontierPoint>,
    /// Dispatch mask, parallel to `points`.
    pub enabled: Vec<bool>,
    /// Units (original-platform indices) each point occupies.
    units: Vec<Vec<usize>>,
    /// `Some(ctx index)` for re-map points, `None` for originals.
    ctx_of: Vec<Option<usize>>,
    n_original: usize,
    ctxs: Vec<DegradedCtx>,
    ctx_by_key: BTreeMap<u64, usize>,
    graph_has_dw: bool,
}

/// Original-platform units a mapping assigns channels to (ascending),
/// plus `dw` when the graph routes depthwise layers there. `to_orig`
/// translates the mapping's accelerator index space into original
/// indices (identity for mappings on the full platform, the survivor
/// list for degraded ones); `base_n` is the original unit count.
fn used_units(
    mapping: &Mapping,
    n_acc: usize,
    to_orig: &[usize],
    dw: Option<usize>,
    base_n: usize,
) -> Vec<usize> {
    let split = mapping.channel_split(n_acc);
    let mut used = vec![false; base_n];
    for counts in split.values() {
        for (i, &c) in counts.iter().enumerate() {
            if c > 0 {
                used[to_orig[i]] = true;
            }
        }
    }
    if let Some(d) = dw {
        used[d] = true;
    }
    (0..base_n).filter(|&u| used[u]).collect()
}

impl HealthTracker {
    /// Wrap a swept frontier. `resolved` is `None` when serving without
    /// a fault plan — every query then degenerates to the healthy fast
    /// path and the driver's behavior is byte-identical to pre-fault
    /// serving.
    pub fn new(
        frontier: &[FrontierPoint],
        platform: &Platform,
        resolved: Option<ResolvedFaults>,
        graph: &Graph,
    ) -> HealthTracker {
        let n_acc = platform.n_acc();
        let graph_has_dw = graph.nodes.iter().any(|n| n.op == Op::DwConv);
        let identity: Vec<usize> = (0..n_acc).collect();
        let dw = if graph_has_dw { Some(platform.dw_acc) } else { None };
        let units = frontier
            .iter()
            .map(|p| used_units(&p.mapping, n_acc, &identity, dw, n_acc))
            .collect();
        HealthTracker {
            resolved,
            base: platform.clone(),
            state: FaultState::healthy(n_acc),
            state_key: FaultState::healthy(n_acc).key(),
            points: frontier.to_vec(),
            enabled: vec![true; frontier.len()],
            units,
            ctx_of: vec![None; frontier.len()],
            n_original: frontier.len(),
            ctxs: Vec::new(),
            ctx_by_key: BTreeMap::new(),
            graph_has_dw,
        }
    }

    /// Bring the mask up to date with the fault state at cycle `t`.
    /// Cheap when the state is unchanged (one key compare); on a state
    /// change, re-derives the mask and (first time per state) builds
    /// the degraded re-mapping.
    pub fn advance(&mut self, t: u64, graph: &Graph) -> Result<()> {
        let Some(r) = &self.resolved else {
            return Ok(());
        };
        let st = r.state_at(t);
        let key = st.key();
        if key == self.state_key {
            return Ok(());
        }
        self.state = st;
        self.state_key = key;
        for i in 0..self.n_original {
            self.enabled[i] = !self.units[i].iter().any(|&u| self.state.is_down(u));
        }
        for e in self.enabled.iter_mut().skip(self.n_original) {
            *e = false;
        }
        let any_disabled = !self.enabled[..self.n_original].iter().all(|&e| e);
        let any_down = (0..self.base.n_acc()).any(|u| self.state.is_down(u));
        if any_down && any_disabled {
            let ci = self.ensure_ctx(graph)?;
            for pi in self.ctxs[ci].point_idx.clone() {
                self.enabled[pi] = true;
            }
        }
        Ok(())
    }

    /// Build (or fetch) the re-mapping for the current fault state.
    fn ensure_ctx(&mut self, graph: &Graph) -> Result<usize> {
        if let Some(&ci) = self.ctx_by_key.get(&self.state_key) {
            return Ok(ci);
        }
        let degraded = self.base.degraded(&self.state)?;
        let survivors = self.state.survivors();
        let n_acc = degraded.n_acc();
        let downs: Vec<&str> = (0..self.base.n_acc())
            .filter(|&u| self.state.is_down(u))
            .map(|u| self.base.accelerators[u].name.as_str())
            .collect();
        let soc = SocConfig::default();
        let ci = self.ctxs.len();
        let mut point_idx = Vec::new();
        let mut seen: Vec<Mapping> = Vec::new();
        for (objective, tag) in [(CostObjective::Latency, "lat"), (CostObjective::Energy, "en")]
        {
            let m = min_cost(graph, &degraded, objective);
            if seen.iter().any(|q| *q == m) {
                continue;
            }
            seen.push(m.clone());
            m.validate(graph, n_acc)?;
            let rep = simulate(graph, &m.channel_split(n_acc), &degraded, soc);
            let dw = if self.graph_has_dw { Some(survivors[degraded.dw_acc]) } else { None };
            let units = used_units(&m, n_acc, &survivors, dw, self.base.n_acc());
            self.points.push(FrontierPoint {
                label: format!("deg[{}]_min_cost_{tag}", downs.join("+")),
                mapping: m,
                cycles: rep.total_cycles,
                latency_ms: rep.latency_ms,
                energy_uj: rep.energy_uj,
                // no calibration pass at serve time — the proxy axis is
                // not meaningful for emergency re-map points
                acc_proxy: 0.0,
            });
            self.enabled.push(false);
            self.units.push(units);
            self.ctx_of.push(Some(ci));
            point_idx.push(self.points.len() - 1);
        }
        self.ctxs.push(DegradedCtx { platform: degraded, point_idx });
        self.ctx_by_key.insert(self.state_key, ci);
        Ok(ci)
    }

    /// The platform a point's plan compiles against: the degraded view
    /// for re-map points, the base platform otherwise.
    pub fn platform_for(&self, point: usize) -> &Platform {
        match self.ctx_of[point] {
            Some(ci) => &self.ctxs[ci].platform,
            None => &self.base,
        }
    }

    /// True for appended re-map points (served in degraded mode).
    pub fn is_degraded_point(&self, point: usize) -> bool {
        self.ctx_of[point].is_some()
    }

    /// Latency stretch for executing `point` starting at cycle `t`:
    /// the worst derating factor over the units the point occupies.
    /// Re-map points return 1.0 — their cycles were scored on the
    /// already-derated platform view.
    pub fn exec_factor(&self, point: usize, t: u64) -> f64 {
        let Some(r) = &self.resolved else {
            return 1.0;
        };
        if self.ctx_of[point].is_some() {
            return 1.0;
        }
        let st = r.state_at(t);
        let mut f = 1.0f64;
        for &u in &self.units[point] {
            let uf = st.factor(u);
            if uf > f {
                f = uf;
            }
        }
        f
    }

    /// Earliest cycle in `[from, to)` at which a unit `point` occupies
    /// is down — the abort point for a batch spanning that window.
    pub fn abort_cycle(&self, point: usize, from: u64, to: u64) -> Option<u64> {
        let r = self.resolved.as_ref()?;
        let mut earliest: Option<u64> = None;
        for &u in &self.units[point] {
            if let Some(c) = r.down_in(u, from, to) {
                match earliest {
                    Some(cur) if c >= cur => {}
                    _ => earliest = Some(c),
                }
            }
        }
        earliest
    }

    /// First fault-state change strictly after `t` (retry horizon for
    /// requests that currently have no dispatchable point).
    pub fn next_change_after(&self, t: u64) -> Option<u64> {
        self.resolved.as_ref().and_then(|r| r.next_change_after(t))
    }

    /// Scripted fault events in the plan (0 without a plan).
    pub fn n_events(&self) -> usize {
        self.resolved.as_ref().map_or(0, ResolvedFaults::n_events)
    }

    /// Currently dispatchable points (error-context helper).
    pub fn enabled_count(&self) -> usize {
        self.enabled.iter().filter(|&&e| e).count()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::hw::faults::{FaultEvent, FaultPlan};
    use crate::model::tinycnn;
    use crate::serve::sweep::{sweep_frontier, SweepCfg};
    use crate::util::pool::ThreadPool;

    /// Frontier + platform fixture on mpsoc4, plus the name and
    /// original index of a unit that point 0's mapping provably uses —
    /// downing *that* unit is guaranteed to disable an original point.
    fn mpsoc4_fixture() -> (Vec<FrontierPoint>, Platform, Graph, String, usize) {
        let g = tinycnn();
        let p = Platform::mpsoc4();
        let pool = ThreadPool::new(2);
        let cfg = SweepCfg { seed: 7, calib: 4, blend_steps: 2 };
        let frontier =
            sweep_frontier(&g, &p, &cfg, &pool, &crate::obs::Recorder::disabled()).unwrap();
        let probe = HealthTracker::new(&frontier, &p, None, &g);
        let victim = probe.units[0][0];
        let vname = p.accelerators[victim].name.clone();
        (frontier, p, g, vname, victim)
    }

    fn tracker(plan: &FaultPlan) -> (HealthTracker, Graph, String, usize) {
        let (frontier, p, g, vname, victim) = mpsoc4_fixture();
        let resolved = plan.resolve(&p).unwrap();
        (HealthTracker::new(&frontier, &p, Some(resolved), &g), g, vname, victim)
    }

    #[test]
    fn mask_follows_unit_down_and_remap_appends() {
        let (_, p, _, vname, _) = mpsoc4_fixture();
        let plan = FaultPlan {
            events: vec![FaultEvent::UnitDown { unit: vname.clone(), at_cycle: 50_000 }],
        };
        let resolved = plan.resolve(&p).unwrap();
        let (frontier, p, g, _, victim) = mpsoc4_fixture();
        let mut t = HealthTracker::new(&frontier, &p, Some(resolved), &g);
        let n0 = t.points.len();
        assert!(t.enabled.iter().all(|&e| e), "healthy: everything enabled");
        t.advance(10_000, &g).unwrap();
        assert_eq!(t.points.len(), n0, "no state change, no remap");
        t.advance(60_000, &g).unwrap();
        // every enabled point avoids the dead unit; point 0 is disabled
        assert!(!t.enabled[0], "point 0 uses the victim and must be masked");
        for (i, &e) in t.enabled.iter().enumerate() {
            if e {
                assert!(!t.units[i].contains(&victim), "enabled point {i} uses a dead unit");
            }
        }
        // disabled originals forced at least one appended remap point
        assert!(t.points.len() > n0, "remap points appended");
        assert!(t.enabled_count() > 0, "degraded mode still dispatches");
        for i in n0..t.points.len() {
            assert!(t.is_degraded_point(i));
            let want = format!("deg[{vname}]");
            assert!(t.points[i].label.starts_with(&want), "{}", t.points[i].label);
            assert!(t.platform_for(i).name.starts_with("mpsoc4~f"));
            assert!(!t.units[i].contains(&victim), "remap touches the dead unit");
        }
        // advancing again at the same state is a no-op (cached ctx)
        let n1 = t.points.len();
        t.advance(70_000, &g).unwrap();
        assert_eq!(t.points.len(), n1);
    }

    #[test]
    fn transient_recovers_and_reuses_cached_remap() {
        let (_, _, _, vname, _) = mpsoc4_fixture();
        let plan = FaultPlan {
            events: vec![
                FaultEvent::Transient {
                    unit: vname.clone(),
                    at_cycle: 10_000,
                    duration: 5_000,
                },
                FaultEvent::Transient { unit: vname, at_cycle: 40_000, duration: 5_000 },
            ],
        };
        let (mut t, g, _, _) = tracker(&plan);
        t.advance(12_000, &g).unwrap();
        let grown = t.points.len();
        assert!(grown > t.n_original, "outage appends remap points");
        let enabled_down: Vec<bool> = t.enabled.clone();
        t.advance(20_000, &g).unwrap();
        assert!(t.enabled[..t.n_original].iter().all(|&e| e), "recovery re-enables");
        assert!(t.enabled[t.n_original..].iter().all(|&e| !e), "remaps parked");
        // the same outage later reuses the cached ctx — no new points
        t.advance(42_000, &g).unwrap();
        assert_eq!(t.points.len(), grown, "recurring state must reuse its remap");
        assert_eq!(t.enabled, enabled_down, "identical state, identical mask");
    }

    #[test]
    fn derated_states_stretch_without_remapping() {
        let (_, _, _, vname, victim) = mpsoc4_fixture();
        let plan = FaultPlan {
            events: vec![FaultEvent::UnitDerated { unit: vname, factor: 3.0, at_cycle: 1_000 }],
        };
        let (mut t, g, _, _) = tracker(&plan);
        let n0 = t.points.len();
        t.advance(2_000, &g).unwrap();
        assert_eq!(t.points.len(), n0, "derating must not trigger remap");
        assert!(t.enabled.iter().all(|&e| e));
        for i in 0..n0 {
            let f = t.exec_factor(i, 2_000);
            if t.units[i].contains(&victim) {
                assert_eq!(f, 3.0, "point {i}");
            } else {
                assert_eq!(f, 1.0, "point {i}");
            }
            assert_eq!(t.exec_factor(i, 500), 1.0, "before the event: no stretch");
        }
        assert_eq!(t.exec_factor(0, 2_000), 3.0, "point 0 uses the derated unit");
    }

    #[test]
    fn abort_cycle_matches_down_windows() {
        let (_, _, _, vname, victim) = mpsoc4_fixture();
        let plan = FaultPlan {
            events: vec![FaultEvent::Transient {
                unit: vname,
                at_cycle: 30_000,
                duration: 10_000,
            }],
        };
        let (t, _g, _, _) = tracker(&plan);
        let using = 0usize; // point 0 uses the victim by construction
        assert_eq!(t.abort_cycle(using, 0, 30_000), None);
        assert_eq!(t.abort_cycle(using, 0, 30_001), Some(30_000));
        assert_eq!(t.abort_cycle(using, 35_000, 90_000), Some(35_000));
        assert_eq!(t.abort_cycle(using, 40_000, 90_000), None);
        if let Some(av) = (0..t.points.len()).find(|&i| !t.units[i].contains(&victim)) {
            assert_eq!(t.abort_cycle(av, 0, u64::MAX), None);
        }
        assert_eq!(t.next_change_after(0), Some(30_000));
        assert_eq!(t.next_change_after(30_000), Some(40_000));
        assert_eq!(t.next_change_after(40_000), None);
    }

    #[test]
    fn no_plan_is_a_pure_pass_through() {
        let g = tinycnn();
        let p = Platform::diana();
        let pool = ThreadPool::new(2);
        let cfg = SweepCfg { seed: 7, calib: 4, blend_steps: 2 };
        let frontier =
            sweep_frontier(&g, &p, &cfg, &pool, &crate::obs::Recorder::disabled()).unwrap();
        let mut t = HealthTracker::new(&frontier, &p, None, &g);
        t.advance(1_000_000, &g).unwrap();
        assert_eq!(t.points.len(), frontier.len());
        assert!(t.enabled.iter().all(|&e| e));
        assert_eq!(t.exec_factor(0, 123), 1.0);
        assert_eq!(t.abort_cycle(0, 0, u64::MAX), None);
        assert_eq!(t.next_change_after(0), None);
        assert_eq!(t.n_events(), 0);
        assert_eq!(t.enabled_count(), frontier.len());
    }
}
