//! Replica cluster: shard one trace across R virtual serve replicas.
//!
//! The cluster layer (docs/ARCHITECTURE.md §Cluster) scales the
//! single-Session closed loop horizontally without giving up the
//! determinism contract:
//!
//! * **Router** — every trace arrival goes to the least-loaded replica
//!   (smallest projected device wait, ties broken by fewer queued
//!   requests then lower replica index). Each replica owns its own
//!   virtual device timeline, health tracker, batcher, retry table and
//!   LRU plan cache.
//! * **Work stealing** — after every event, a fully idle replica may
//!   steal up to [`ClusterOpts::steal_max`] of the oldest queued
//!   requests from the most-backlogged busy replica. Stolen requests
//!   are re-stamped to the steal cycle but keep their *first* arrival
//!   for queue-time/SLA accounting (the same `orig_arrival` table the
//!   retry path uses).
//! * **Continuous batching** — with [`ClusterOpts::continuous`] on, a
//!   flushed batch becomes an *in-flight* window on the device
//!   timeline; later same-mapping arrivals join it (up to `max_batch`)
//!   instead of waiting for the next flush-and-wait cycle. With it off
//!   every replica behaves byte-identically to the single-session
//!   loop — the differential pin in `tests/cluster_props.rs`.
//! * **Compile-ahead gate** — [`ClusterOpts::compile_cycles`] models
//!   async plan compilation: the first batch on a frontier point
//!   cannot *start* before `first_flush + compile_cycles`, but the
//!   replica keeps serving already-warm mappings in the meantime
//!   (compilation overlaps serving instead of stalling the queue).
//!
//! Everything is single-threaded virtual time — the thread pool only
//! accelerates the real engine work inside each batch — so the
//! [`ClusterReport::deterministic_digest`] is invariant across worker
//! thread counts and host schedules.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::synth::gen_sample;
use crate::exp::store;
use crate::hw::Platform;
use crate::model::Graph;
use crate::obs::{ctr, EventKind, FlushReason, Recorder};
use crate::quant::{KernelBackend, ParamSet, QuantNet, QuantPlan};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;

use super::batcher::{Batch, Batcher, PlanCache, Request};
use super::dispatch::{dispatch_filtered, fastest_filtered, Sla};
use super::health::HealthTracker;
use super::metrics::{RequestOutcome, ServeMetrics, ServeReport, Tenant};
use super::trace::Trace;
use super::{advance_traced, push_traced, Admission, RetryState, SeedLookup, ServeError, ServeOpts};

/// Cluster report schema version (envelope kind `cluster_report`).
/// v2 added the per-(model, tenant) accounting rows (`model_rows`) and
/// multi-model serving.
pub const CLUSTER_SCHEMA: u32 = 2;

/// Cluster-level serve knobs wrapping the per-replica [`ServeOpts`].
#[derive(Clone, Debug)]
pub struct ClusterOpts {
    /// Replica count (>= 1). Each replica is an independent virtual
    /// device with its own timeline, batcher and plan cache.
    pub replicas: usize,
    /// Per-replica closed-loop knobs (batching, faults, admission,
    /// retries). `serve.n_requests` sizes the synthesized trace when
    /// no explicit trace is given.
    pub serve: ServeOpts,
    /// Continuous batching: admit same-mapping arrivals into the
    /// replica's in-flight batch instead of flush-and-wait. Off
    /// reproduces the single-session loop exactly.
    pub continuous: bool,
    /// Most requests one work-stealing event may move (0 disables
    /// stealing).
    pub steal_max: usize,
    /// Virtual cycles the first batch on a frontier point waits for
    /// plan compilation (0 = plans are warm, the historical behavior).
    pub compile_cycles: u64,
    /// Per-replica LRU plan-cache capacity.
    pub plan_cache_cap: usize,
}

impl Default for ClusterOpts {
    fn default() -> Self {
        ClusterOpts {
            replicas: 1,
            serve: ServeOpts::default(),
            continuous: true,
            steal_max: 2,
            compile_cycles: 0,
            plan_cache_cap: 8,
        }
    }
}

/// Per-(model, tenant) accounting row in the cluster dashboard (the
/// multi-model refinement of [`TenantRow`]). The conservation identity
/// holds per row: `arrivals == served + shed + failed`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelTenantRow {
    /// Model name from the trace.
    pub model: String,
    /// Tenant label from the trace.
    pub tenant: String,
    /// Requests the trace carried for this (model, tenant).
    pub arrivals: u64,
    /// Requests served to completion.
    pub served: u64,
    /// Served requests that met their SLA.
    pub sla_hits: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests that exhausted their retries.
    pub failed: u64,
}

/// Per-tenant accounting row in the cluster dashboard.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantRow {
    /// Tenant label from the trace.
    pub tenant: String,
    /// Requests the trace carried for this tenant.
    pub arrivals: u64,
    /// Requests served to completion.
    pub served: u64,
    /// Served requests that met their SLA.
    pub sla_hits: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests that exhausted their retries.
    pub failed: u64,
}

/// Aggregated result of one cluster run: the per-replica
/// [`ServeReport`]s plus router/steal/compile counters and per-tenant
/// rows. Satisfies the same determinism contract as [`ServeReport`]:
/// every virtual-time field is a pure function of
/// (trace, platform, [`ClusterOpts`]).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Model served.
    pub model: String,
    /// Platform served on.
    pub platform: String,
    /// One report per replica, indexed by replica id.
    pub replicas: Vec<ServeReport>,
    /// Arrivals the router sent to each replica.
    pub dispatched: Vec<u64>,
    /// Work-stealing events that moved at least one request.
    pub steals: u64,
    /// Requests moved by work stealing in total.
    pub stolen_requests: u64,
    /// Frontier points that paid the compile-ahead gate (first batch
    /// per point per replica).
    pub cold_compiles: u64,
    /// Requests served to completion across all replicas.
    pub total_requests: u64,
    /// Requests shed by admission control across all replicas.
    pub shed_requests: u64,
    /// Requests that exhausted retries across all replicas.
    pub failed_requests: u64,
    /// Wall of the cluster's virtual timeline: latest replica
    /// end-cycle, in milliseconds.
    pub makespan_ms: f64,
    /// Served requests per *virtual* second (served / makespan) — the
    /// deterministic throughput figure the bench gate compares across
    /// replica counts.
    pub virtual_img_s: f64,
    /// Per-tenant accounting, sorted by tenant label.
    pub tenants: Vec<TenantRow>,
    /// Per-(model, tenant) accounting, sorted by (model, tenant). One
    /// group per model on single-model runs; conservation holds per
    /// row (`arrivals == served + shed + failed`).
    pub model_rows: Vec<ModelTenantRow>,
}

impl ClusterReport {
    /// Conservation identity: served + shed + failed. Tests pin this
    /// to the trace length — every request ends in exactly one bucket.
    pub fn accounted(&self) -> u64 {
        self.total_requests + self.shed_requests + self.failed_requests
    }

    /// FNV-1a digest over every deterministic field (replica digests,
    /// router counters, tenant rows, virtual metrics). Invariant
    /// across worker thread counts and host schedules; sensitive to
    /// trace, platform and every [`ClusterOpts`] knob.
    pub fn deterministic_digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(self.model.as_bytes());
        eat(self.platform.as_bytes());
        eat(&(self.replicas.len() as u64).to_le_bytes());
        for r in &self.replicas {
            eat(&r.deterministic_digest().to_le_bytes());
        }
        for d in &self.dispatched {
            eat(&d.to_le_bytes());
        }
        eat(&self.steals.to_le_bytes());
        eat(&self.stolen_requests.to_le_bytes());
        eat(&self.cold_compiles.to_le_bytes());
        eat(&self.total_requests.to_le_bytes());
        eat(&self.shed_requests.to_le_bytes());
        eat(&self.failed_requests.to_le_bytes());
        eat(&self.makespan_ms.to_bits().to_le_bytes());
        eat(&self.virtual_img_s.to_bits().to_le_bytes());
        for t in &self.tenants {
            eat(t.tenant.as_bytes());
            eat(&t.arrivals.to_le_bytes());
            eat(&t.served.to_le_bytes());
            eat(&t.sla_hits.to_le_bytes());
            eat(&t.shed.to_le_bytes());
            eat(&t.failed.to_le_bytes());
        }
        for m in &self.model_rows {
            eat(m.model.as_bytes());
            eat(m.tenant.as_bytes());
            eat(&m.arrivals.to_le_bytes());
            eat(&m.served.to_le_bytes());
            eat(&m.sla_hits.to_le_bytes());
            eat(&m.shed.to_le_bytes());
            eat(&m.failed.to_le_bytes());
        }
        h
    }

    /// Multi-line human dashboard (mirrors the single-session one).
    pub fn dashboard(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "cluster serve: {} on {} — {} replica(s)\n",
            self.model,
            self.platform,
            self.replicas.len()
        ));
        out.push_str(&format!(
            "  requests: {} served / {} shed / {} failed   virtual {:.1} img/s   \
             makespan {:.3} ms\n",
            self.total_requests,
            self.shed_requests,
            self.failed_requests,
            self.virtual_img_s,
            self.makespan_ms
        ));
        out.push_str(&format!(
            "  router: dispatched {:?}, {} steal(s) moving {} request(s), {} cold \
             compile(s)\n",
            self.dispatched, self.steals, self.stolen_requests, self.cold_compiles
        ));
        for (j, r) in self.replicas.iter().enumerate() {
            out.push_str(&format!(
                "  replica {j}: {} req in {} batch(es), p95 {:.3} ms, sla {:.1}%\n",
                r.total_requests,
                r.total_batches,
                r.p95_ms,
                r.sla_hit_rate * 100.0
            ));
        }
        for t in &self.tenants {
            out.push_str(&format!(
                "  tenant {}: {} arrived, {} served, {} sla-hit, {} shed, {} failed\n",
                t.tenant, t.arrivals, t.served, t.sla_hits, t.shed, t.failed
            ));
        }
        let distinct_models =
            self.model_rows.iter().map(|m| m.model.as_str()).collect::<BTreeSet<_>>();
        if distinct_models.len() > 1 {
            for m in &self.model_rows {
                out.push_str(&format!(
                    "  model {} / {}: {} arrived, {} served, {} sla-hit, {} shed, {} \
                     failed\n",
                    m.model, m.tenant, m.arrivals, m.served, m.sla_hits, m.shed, m.failed
                ));
            }
        }
        out
    }

    pub(crate) fn to_json(&self) -> Json {
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", Json::str(t.tenant.clone())),
                    ("arrivals", Json::num(t.arrivals as f64)),
                    ("served", Json::num(t.served as f64)),
                    ("sla_hits", Json::num(t.sla_hits as f64)),
                    ("shed", Json::num(t.shed as f64)),
                    ("failed", Json::num(t.failed as f64)),
                ])
            })
            .collect();
        let model_rows = self
            .model_rows
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("model", Json::str(m.model.clone())),
                    ("tenant", Json::str(m.tenant.clone())),
                    ("arrivals", Json::num(m.arrivals as f64)),
                    ("served", Json::num(m.served as f64)),
                    ("sla_hits", Json::num(m.sla_hits as f64)),
                    ("shed", Json::num(m.shed as f64)),
                    ("failed", Json::num(m.failed as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("platform", Json::str(self.platform.clone())),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "dispatched",
                Json::Arr(self.dispatched.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("steals", Json::num(self.steals as f64)),
            ("stolen_requests", Json::num(self.stolen_requests as f64)),
            ("cold_compiles", Json::num(self.cold_compiles as f64)),
            ("total_requests", Json::num(self.total_requests as f64)),
            ("shed_requests", Json::num(self.shed_requests as f64)),
            ("failed_requests", Json::num(self.failed_requests as f64)),
            ("makespan_ms", Json::num(self.makespan_ms)),
            ("virtual_img_s", Json::num(self.virtual_img_s)),
            ("tenants", Json::Arr(tenants)),
            ("model_rows", Json::Arr(model_rows)),
        ])
    }

    pub(crate) fn from_json(v: &Json) -> Result<ClusterReport> {
        let replicas = v
            .req("replicas")?
            .as_arr()
            .ok_or_else(|| anyhow!("cluster report: replicas must be an array"))?
            .iter()
            .map(ServeReport::from_json)
            .collect::<Result<Vec<ServeReport>>>()?;
        let dispatched = v
            .req("dispatched")?
            .as_arr()
            .ok_or_else(|| anyhow!("cluster report: dispatched must be an array"))?
            .iter()
            .map(|d| {
                d.as_f64()
                    .map(|x| x as u64)
                    .ok_or_else(|| anyhow!("cluster report: dispatched entries are numbers"))
            })
            .collect::<Result<Vec<u64>>>()?;
        let tenants = v
            .req("tenants")?
            .as_arr()
            .ok_or_else(|| anyhow!("cluster report: tenants must be an array"))?
            .iter()
            .map(|t| -> Result<TenantRow> {
                Ok(TenantRow {
                    tenant: t.req("tenant")?.as_str().unwrap_or("").to_string(),
                    arrivals: t.req_f64("arrivals")? as u64,
                    served: t.req_f64("served")? as u64,
                    sla_hits: t.req_f64("sla_hits")? as u64,
                    shed: t.req_f64("shed")? as u64,
                    failed: t.req_f64("failed")? as u64,
                })
            })
            .collect::<Result<Vec<TenantRow>>>()?;
        let model_rows = v
            .req("model_rows")?
            .as_arr()
            .ok_or_else(|| anyhow!("cluster report: model_rows must be an array"))?
            .iter()
            .map(|m| -> Result<ModelTenantRow> {
                Ok(ModelTenantRow {
                    model: m.req("model")?.as_str().unwrap_or("").to_string(),
                    tenant: m.req("tenant")?.as_str().unwrap_or("").to_string(),
                    arrivals: m.req_f64("arrivals")? as u64,
                    served: m.req_f64("served")? as u64,
                    sla_hits: m.req_f64("sla_hits")? as u64,
                    shed: m.req_f64("shed")? as u64,
                    failed: m.req_f64("failed")? as u64,
                })
            })
            .collect::<Result<Vec<ModelTenantRow>>>()?;
        Ok(ClusterReport {
            model: v.req("model")?.as_str().unwrap_or("").to_string(),
            platform: v.req("platform")?.as_str().unwrap_or("").to_string(),
            replicas,
            dispatched,
            steals: v.req_f64("steals")? as u64,
            stolen_requests: v.req_f64("stolen_requests")? as u64,
            cold_compiles: v.req_f64("cold_compiles")? as u64,
            total_requests: v.req_f64("total_requests")? as u64,
            shed_requests: v.req_f64("shed_requests")? as u64,
            failed_requests: v.req_f64("failed_requests")? as u64,
            makespan_ms: v.req_f64("makespan_ms")?,
            virtual_img_s: v.req_f64("virtual_img_s")?,
            tenants,
            model_rows,
        })
    }
}

/// Report path for a (model, platform) cluster run under `results_dir`.
pub fn cluster_report_path(results_dir: &Path, model: &str, platform: &str) -> PathBuf {
    results_dir.join(format!("cluster_{model}_{platform}.json"))
}

/// Persist a cluster report atomically under the versioned envelope.
pub fn save_cluster_report(path: &Path, report: &ClusterReport) -> Result<()> {
    store::save_versioned(path, "cluster_report", CLUSTER_SCHEMA, report.to_json())
}

/// Load a persisted cluster report (clear error on kind/schema
/// mismatch).
pub fn load_cluster_report(path: &Path) -> Result<ClusterReport> {
    ClusterReport::from_json(&store::load_versioned(path, "cluster_report", CLUSTER_SCHEMA)?)
}

// ---------------------------------------------------------------------------
// the deterministic multi-replica event loop
// ---------------------------------------------------------------------------

/// One model in the serving set: its graph, parameters and swept
/// frontier, borrowed from the session for the duration of the run.
/// Index order in the slice defines [`Request::model`].
pub(crate) struct ClusterModel<'a> {
    /// The model's graph.
    pub graph: &'a Graph,
    /// The model's weights/calibration.
    pub params: &'a ParamSet<'a>,
    /// The model's Pareto frontier on the serving platform.
    pub frontier: &'a [super::FrontierPoint],
}

/// A batch the replica launched on its device window and may still
/// extend with same-(model, mapping) joiners (continuous batching).
struct InFlight {
    model: u32,
    point: usize,
    start: u64,
    per_img: u64,
    done: u64,
    derated: bool,
    requests: Vec<Request>,
}

/// One virtual serve replica: the same state `run_serve` keeps in
/// locals, boxed per replica.
struct Replica {
    /// Replica index (obs events carry it as the track id).
    id: u32,
    /// One health tracker per model in the serving set (each with its
    /// own independently-resolved fault plan and degraded re-mappings).
    trackers: Vec<HealthTracker>,
    batcher: Batcher,
    stats: ServeMetrics,
    retry: RetryState,
    plans: PlanCache,
    device_free: u64,
    inflight: Option<InFlight>,
    /// Per-(model, point) compile-ahead gate: cycle the plan is warm.
    warm_at: BTreeMap<(u32, usize), u64>,
}

impl Replica {
    /// Advance every model's fault tracker to `t` (a replica has one
    /// device timeline, so all trackers move together).
    fn advance_all(&mut self, t: u64, models: &[ClusterModel<'_>], rec: &Recorder) -> Result<()> {
        for (mi, tracker) in self.trackers.iter_mut().enumerate() {
            advance_traced(tracker, t, models[mi].graph, rec, self.id)?;
        }
        Ok(())
    }
}

/// Shared read-only context threaded through the event handlers.
struct Ctx<'a> {
    models: &'a [ClusterModel<'a>],
    pool: &'a ThreadPool,
    opts: &'a ClusterOpts,
    seeds: SeedLookup<'a>,
    backend: KernelBackend,
    rec: &'a Recorder,
}

/// Mutably borrow two distinct replicas.
fn two(v: &mut [Replica], i: usize, j: usize) -> (&mut Replica, &mut Replica) {
    debug_assert_ne!(i, j);
    if i < j {
        let (a, b) = v.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = v.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

/// Least-loaded routing: smallest projected device wait, then fewest
/// queued requests, then lowest index.
fn route(replicas: &[Replica], now: u64) -> usize {
    let mut best = 0usize;
    let mut best_key = (u64::MAX, usize::MAX);
    for (j, rep) in replicas.iter().enumerate() {
        let key = (rep.device_free.saturating_sub(now), rep.batcher.pending());
        if key < best_key {
            best_key = key;
            best = j;
        }
    }
    best
}

/// First-flush compile gate for `(model, point)`: the cycle its plan
/// is warm. A zero-cycle gate is free and not counted as cold.
fn warm_gate(
    rep: &mut Replica,
    model: u32,
    point: usize,
    t: u64,
    compile_cycles: u64,
    cold: &mut u64,
) -> u64 {
    if compile_cycles == 0 {
        return t;
    }
    *rep.warm_at.entry((model, point)).or_insert_with(|| {
        *cold += 1;
        t.saturating_add(compile_cycles)
    })
}

/// A batch left the batcher: launch it as the replica's in-flight
/// window (continuous mode, device idle) or execute it flush-style on
/// the virtual timeline behind whatever is already running.
fn handle_batch(rep: &mut Replica, b: &Batch, ctx: &Ctx<'_>, cold: &mut u64) -> Result<()> {
    let gate = warm_gate(rep, b.model, b.point, b.flushed_at, ctx.opts.compile_cycles, cold);
    let mi = b.model as usize;
    if ctx.opts.continuous && rep.inflight.is_none() {
        let start = b.flushed_at.max(rep.device_free).max(gate);
        let tracker = &rep.trackers[mi];
        let fp = &tracker.points[b.point];
        let factor = tracker.exec_factor(b.point, start);
        let per_img = if factor > 1.0 {
            (fp.cycles as f64 * factor).ceil() as u64
        } else {
            fp.cycles
        };
        let done = start + ctx.opts.serve.launch_cycles + per_img * b.requests.len() as u64;
        rep.device_free = done;
        rep.inflight = Some(InFlight {
            model: b.model,
            point: b.point,
            start,
            per_img,
            done,
            derated: factor > 1.0,
            requests: b.requests.clone(),
        });
        return Ok(());
    }
    rep.device_free = rep.device_free.max(gate);
    super::exec_batch(
        b,
        ctx.models[mi].graph,
        ctx.models[mi].params,
        &rep.trackers[mi],
        &ctx.opts.serve,
        &ctx.seeds,
        ctx.pool,
        &mut rep.plans,
        &mut rep.stats,
        &mut rep.device_free,
        &mut rep.retry,
        ctx.backend,
        ctx.rec,
        rep.id,
    )
}

/// A dispatched request enters the replica: join the in-flight batch
/// when continuous batching allows it, otherwise queue it (flushing
/// through [`handle_batch`] when the queue fills).
fn serve_on(rep: &mut Replica, q: Request, ctx: &Ctx<'_>, cold: &mut u64) -> Result<()> {
    if ctx.opts.continuous {
        if let Some(inf) = rep.inflight.as_mut() {
            // joining is only sound while the window is still open
            // (now < done), has capacity, runs the same model's plan,
            // and no later batch already queued behind it on the device
            if inf.model == q.model
                && inf.point == q.point
                && inf.requests.len() < ctx.opts.serve.max_batch
                && q.arrival < inf.done
                && rep.device_free == inf.done
            {
                inf.requests.push(q);
                inf.done += inf.per_img;
                rep.device_free = inf.done;
                ctx.rec.virt(
                    rep.id,
                    q.arrival,
                    EventKind::ContinuousJoin { req: q.id, done: inf.done },
                );
                return Ok(());
            }
        }
    }
    if let Some(b) = push_traced(&mut rep.batcher, q, ctx.rec, rep.id) {
        handle_batch(rep, &b, ctx, cold)?;
    }
    Ok(())
}

/// The in-flight window closed: abort it if its unit died under it,
/// otherwise run the real engine once over the final member set and
/// record every outcome. `ev_now` is the loop's current virtual cycle
/// — obs events are stamped there so the per-replica event stream
/// stays monotone (the window's real start/done ride in the payload).
fn complete_inflight(rep: &mut Replica, inf: InFlight, ctx: &Ctx<'_>, ev_now: u64) -> Result<()> {
    let bsz = inf.requests.len();
    let mi = inf.model as usize;
    let (graph, params) = (ctx.models[mi].graph, ctx.models[mi].params);
    if let Some(abort_at) = rep.trackers[mi].abort_cycle(inf.point, inf.start, inf.done) {
        rep.stats.registry_mut().inc(ctr::BATCH_ABORTS);
        ctx.rec.virt(rep.id, ev_now, EventKind::BatchAbort { point: inf.point, at: abort_at });
        if rep.device_free == inf.done {
            // nothing queued behind the window: rewind the device to
            // the abort + cleanup cost, as the flush path does
            rep.device_free = abort_at.saturating_add(ctx.opts.serve.launch_cycles);
        }
        let retry_at = abort_at.saturating_add(ctx.opts.serve.retry_backoff.max(1));
        for r in &inf.requests {
            rep.retry.schedule(
                r,
                Some(retry_at),
                ctx.opts.serve.max_retries,
                &mut rep.stats,
                ctx.rec,
                rep.id,
                ev_now,
            );
        }
        return Ok(());
    }
    let fp = &rep.trackers[mi].points[inf.point];
    let platform = rep.trackers[mi].platform_for(inf.point);
    let (c, h, w) = graph.input_shape;
    let mut x = Vec::with_capacity(bsz * c * h * w);
    for r in &inf.requests {
        let cls = (r.id % graph.classes as u64) as u32;
        x.extend_from_slice(&gen_sample(ctx.seeds.seed_for(r.id), 1, r.id, cls, h, w));
    }
    let key = QuantPlan::cache_key(
        &graph.name,
        graph.spec_hash(),
        &platform.name,
        &fp.mapping,
        ctx.backend,
    );
    let compile_before = rep.plans.compile_ns;
    let misses_before = rep.plans.misses;
    let t0 = Instant::now();
    // at ObsLevel::Full the traced walk runs instead of the pooled one
    // (bit-identical numerics, single-threaded, per-node timed)
    let mut traced = None;
    {
        let net = rep.plans.get_or_compile(key, &fp.mapping, || {
            QuantNet::compile_params_backend(params, graph, &fp.mapping, platform, ctx.backend)
        })?;
        if ctx.rec.full() {
            let t_ns = ctx.rec.now_ns();
            let (y, spans) = net.forward_traced(&x, bsz)?;
            std::hint::black_box(&y);
            traced = Some((net.isa().name(), t_ns, spans));
        } else {
            let y = net.forward_pool(&x, bsz, ctx.pool)?;
            std::hint::black_box(&y);
        }
    }
    let wall = t0.elapsed().as_nanos() as u64;
    let engine_ns = wall.saturating_sub(rep.plans.compile_ns - compile_before);
    rep.stats.record_batch(engine_ns);
    if ctx.rec.enabled() {
        let kind = if rep.plans.misses > misses_before {
            EventKind::PlanCacheMiss { key }
        } else {
            EventKind::PlanCacheHit { key }
        };
        ctx.rec.virt(rep.id, ev_now, kind);
    }
    if let Some((isa, t_ns, spans)) = traced {
        ctx.rec.wall(
            rep.id,
            t_ns,
            EventKind::EngineRun {
                point: inf.point,
                batch: bsz,
                threads: ctx.pool.threads(),
                isa: isa.to_string(),
                dur_ns: engine_ns,
            },
        );
        for s in spans {
            ctx.rec.wall(
                rep.id,
                t_ns + s.start_ns,
                EventKind::KernelOp { node: s.node, kind: s.kind, algo: s.algo, dur_ns: s.dur_ns },
            );
        }
    }
    if ctx.rec.enabled() {
        ctx.rec.virt(
            rep.id,
            ev_now,
            EventKind::BatchExec {
                model: graph.name.clone(),
                point: inf.point,
                label: fp.label.clone(),
                start: inf.start,
                done: inf.done,
                size: bsz,
                per_img: inf.per_img,
                launch: ctx.opts.serve.launch_cycles,
                derated: inf.derated,
                energy_uj: fp.energy_uj,
                members: inf.requests.iter().map(|r| (r.id, rep.retry.orig(r))).collect(),
            },
        );
    }
    let compute = inf.done - inf.start;
    for r in &inf.requests {
        let orig = rep.retry.orig(r);
        let total = inf.done.saturating_sub(orig);
        let met = match r.sla {
            Sla::MinEnergy => true,
            Sla::LatencyBudget(b) => total <= b,
        };
        let degraded = rep.trackers[mi].is_degraded_point(inf.point)
            || inf.derated
            || rep.retry.degraded_ids.contains(&r.id);
        rep.stats.record(RequestOutcome {
            id: r.id,
            model: inf.model,
            point: inf.point,
            queue_cycles: inf.start.saturating_sub(orig),
            compute_cycles: compute,
            sla_met: met,
            batch_size: bsz,
            energy_uj: fp.energy_uj,
            degraded,
            tenant: Tenant::from_sla(&r.sla),
        });
    }
    Ok(())
}

/// Dispatch one request on `rep` under its current health mask, or
/// schedule a retry at the next fault-state change.
fn dispatch_or_retry(
    rep: &mut Replica,
    r: Request,
    now: u64,
    ctx: &Ctx<'_>,
    cold: &mut u64,
) -> Result<()> {
    let mi = r.model as usize;
    let d = {
        let tr = &rep.trackers[mi];
        dispatch_filtered(&tr.points, |x| tr.enabled[x], r.sla)
    };
    match d {
        Some(d) => {
            if ctx.rec.enabled() {
                ctx.rec.virt(
                    rep.id,
                    now,
                    EventKind::Dispatch {
                        req: r.id,
                        point: d.point,
                        label: rep.trackers[mi].points[d.point].label.clone(),
                        sla_met: d.sla_met,
                        degraded: rep.retry.degraded_ids.contains(&r.id),
                    },
                );
            }
            serve_on(rep, Request { point: d.point, ..r }, ctx, cold)
        }
        None => {
            ctx.rec.virt(
                rep.id,
                now,
                EventKind::DispatchDefer {
                    req: r.id,
                    enabled: rep.trackers[mi].enabled_count(),
                    total: rep.trackers[mi].points.len(),
                },
            );
            let at = rep.trackers[mi].next_change_after(now);
            rep.retry.schedule(
                &r,
                at,
                ctx.opts.serve.max_retries,
                &mut rep.stats,
                ctx.rec,
                rep.id,
                now,
            );
            Ok(())
        }
    }
}

/// Bounded work stealing: each fully idle replica may pull the oldest
/// `steal_max` queued requests from the most-backlogged busy replica.
#[allow(clippy::too_many_arguments)]
fn steal_pass(
    replicas: &mut [Replica],
    now: u64,
    ctx: &Ctx<'_>,
    cold: &mut u64,
    steals: &mut u64,
    stolen_requests: &mut u64,
) -> Result<()> {
    if ctx.opts.steal_max == 0 || replicas.len() < 2 {
        return Ok(());
    }
    for t in 0..replicas.len() {
        let idle = {
            let rep = &replicas[t];
            rep.inflight.is_none() && rep.batcher.pending() == 0 && rep.device_free <= now
        };
        if !idle {
            continue;
        }
        let mut victim: Option<(usize, usize)> = None; // (pending, index)
        for (v, rep) in replicas.iter().enumerate() {
            if v == t {
                continue;
            }
            let p = rep.batcher.pending();
            if rep.device_free > now && p > 0 && victim.map_or(true, |(bp, _)| p > bp) {
                victim = Some((p, v));
            }
        }
        let Some((_, v)) = victim else {
            continue;
        };
        let (thief, vict) = two(replicas, t, v);
        let stolen = vict.batcher.steal_oldest(ctx.opts.steal_max);
        if stolen.is_empty() {
            continue;
        }
        *steals += 1;
        *stolen_requests += stolen.len() as u64;
        ctx.rec.virt(
            thief.id,
            now,
            EventKind::Steal { from: vict.id, to: thief.id, moved: stolen.len() },
        );
        thief.advance_all(now, ctx.models, ctx.rec)?;
        for r in stolen {
            // queue time and SLA accounting span the move: the thief
            // inherits the request's first arrival, attempt count and
            // degraded mark before re-stamping it to the steal cycle
            let orig = vict.retry.orig(&r);
            thief.retry.orig_arrival.entry(r.id).or_insert(orig);
            if let Some(&att) = vict.retry.attempts.get(&r.id) {
                let e = thief.retry.attempts.entry(r.id).or_insert(0);
                *e = (*e).max(att);
            }
            if vict.retry.degraded_ids.contains(&r.id) {
                thief.retry.degraded_ids.insert(r.id);
            }
            let restamped = Request { arrival: now, ..r };
            dispatch_or_retry(thief, restamped, now, ctx, cold)?;
        }
    }
    Ok(())
}

/// Run the deterministic multi-replica closed loop over `trace` for a
/// single model. Thin wrapper over [`run_cluster_multi`]; with one
/// model every multi-model code path degenerates to the historical
/// behavior, so reports and digests are unchanged. Crate-internal: the
/// public surface is
/// [`Session::serve_cluster`](crate::api::Session::serve_cluster).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_cluster(
    graph: &Graph,
    platform: &Platform,
    params: &ParamSet<'_>,
    frontier: &[super::FrontierPoint],
    pool: &ThreadPool,
    trace: &Trace,
    opts: &ClusterOpts,
    backend: KernelBackend,
    rec: &Recorder,
) -> Result<ClusterReport> {
    run_cluster_multi(
        &[ClusterModel { graph, params, frontier }],
        platform,
        pool,
        trace,
        opts,
        backend,
        rec,
    )
}

/// The multi-model closed loop: every replica serves the whole model
/// set (one health tracker per model, a shared batcher keyed by
/// (model, point), one device timeline), and each trace record routes
/// to its model by name. Crate-internal: the public surface is
/// [`Session::serve_multi`](crate::api::Session::serve_multi).
pub(crate) fn run_cluster_multi(
    models: &[ClusterModel<'_>],
    platform: &Platform,
    pool: &ThreadPool,
    trace: &Trace,
    opts: &ClusterOpts,
    backend: KernelBackend,
    rec: &Recorder,
) -> Result<ClusterReport> {
    if models.is_empty() {
        return Err(anyhow!("cluster: the serving set has no models"));
    }
    for m in models {
        if m.frontier.is_empty() {
            return Err(ServeError::EmptyFrontier {
                model: m.graph.name.clone(),
                platform: platform.name.clone(),
            }
            .into());
        }
    }
    let names: Vec<String> = models.iter().map(|m| m.graph.name.clone()).collect();
    let reqs = trace.to_requests_routed(&names).map_err(|i| {
        anyhow!(
            "cluster: trace record {} targets model '{}' but the session serves {:?}",
            i,
            trace.records[i].model,
            names
        )
    })?;
    let n_replicas = opts.replicas.max(1);
    let seed_table = trace.seeds();
    let fallback = seed_table.first().copied().unwrap_or(0);
    let ctx = Ctx {
        models,
        pool,
        opts,
        seeds: SeedLookup::PerRequest { seeds: &seed_table, fallback },
        backend,
        rec,
    };
    let mut replicas = Vec::with_capacity(n_replicas);
    for id in 0..n_replicas {
        let mut trackers = Vec::with_capacity(models.len());
        let mut n_events = 0u64;
        for m in models {
            let resolved = match &opts.serve.fault_plan {
                Some(plan) => Some(plan.resolve(platform)?),
                None => None,
            };
            let tracker = HealthTracker::new(m.frontier, platform, resolved, m.graph);
            n_events += tracker.n_events() as u64;
            trackers.push(tracker);
        }
        let mut stats = ServeMetrics::new();
        stats.registry_mut().set(ctr::FAULTS_INJECTED, n_events);
        replicas.push(Replica {
            id: id as u32,
            trackers,
            batcher: Batcher::new(opts.serve.max_batch, opts.serve.max_wait),
            stats,
            retry: RetryState::new(),
            plans: PlanCache::new(opts.plan_cache_cap),
            device_free: 0,
            inflight: None,
            warm_at: BTreeMap::new(),
        });
    }

    let mut dispatched = vec![0u64; n_replicas];
    let mut shed_ids: Vec<u64> = Vec::new();
    let mut cold_compiles = 0u64;
    let mut steals = 0u64;
    let mut stolen_requests = 0u64;

    // the same virtual-time event loop as `run_serve`, generalized to
    // R replicas: earliest event first with ties broken by source rank
    // (retry 0, arrival 1, queue deadline 2, in-flight completion 3)
    // then replica index — all state is BTreeMap-ordered, so the
    // schedule is a pure function of (trace, platform, opts)
    let mut i = 0usize;
    let mut tail_now = reqs.last().map(|r| r.arrival).unwrap_or(0);
    loop {
        let more = i < reqs.len()
            || replicas.iter().any(|r| {
                r.batcher.pending() > 0 || r.retry.next_time().is_some() || r.inflight.is_some()
            });
        if !more {
            break;
        }
        let next_arrival = reqs.get(i).map(|r| r.arrival);
        let quiet = next_arrival.is_none()
            && replicas
                .iter()
                .all(|r| r.retry.next_time().is_none() && r.inflight.is_none());
        if quiet {
            // stream over, nothing in flight: drain every replica's
            // residual queues at the tail cycle (run_serve's tail rule)
            for rep in replicas.iter_mut() {
                let batches = rep.batcher.drain(tail_now);
                for b in batches {
                    rec.virt(
                        rep.id,
                        tail_now,
                        EventKind::BatchFlush {
                            point: b.point,
                            size: b.requests.len(),
                            reason: FlushReason::Drain,
                        },
                    );
                    handle_batch(rep, &b, &ctx, &mut cold_compiles)?;
                }
                // continuous mode may have left the drained batch in
                // flight — close it immediately, the stream is over
                if let Some(inf) = rep.inflight.take() {
                    let ev_now = tail_now.max(inf.done);
                    tail_now = ev_now;
                    rep.advance_all(inf.done, models, rec)?;
                    complete_inflight(rep, inf, &ctx, ev_now)?;
                }
            }
            continue;
        }
        let mut best: Option<(u64, u8, usize)> = None;
        let mut consider = |cand: Option<(u64, u8, usize)>| {
            if let Some(c) = cand {
                if best.map_or(true, |b| c < b) {
                    best = Some(c);
                }
            }
        };
        for (j, rep) in replicas.iter().enumerate() {
            consider(rep.retry.next_time().map(|t| (t, 0u8, j)));
        }
        consider(next_arrival.map(|t| (t, 1u8, 0)));
        for (j, rep) in replicas.iter().enumerate() {
            consider(rep.batcher.next_deadline().map(|t| (t, 2u8, j)));
        }
        for (j, rep) in replicas.iter().enumerate() {
            consider(rep.inflight.as_ref().map(|f| (f.done, 3u8, j)));
        }
        let Some((now, source, j)) = best else {
            let pending = replicas.iter().map(|r| r.batcher.pending()).sum();
            return Err(ServeError::MissingDeadline { pending }.into());
        };
        match source {
            // scheduled retries: re-dispatch under the replica's mask
            0 => {
                tail_now = tail_now.max(now);
                let rep = &mut replicas[j];
                rep.advance_all(now, models, rec)?;
                for r in rep.retry.pop_at(now) {
                    dispatch_or_retry(rep, r, now, &ctx, &mut cold_compiles)?;
                }
            }
            // arrivals: route, then the single-session admission path
            1 => {
                let r = reqs[i];
                i += 1;
                let target = route(&replicas, now);
                dispatched[target] += 1;
                let rep = &mut replicas[target];
                rep.advance_all(r.arrival, models, rec)?;
                let wait = rep.device_free.saturating_sub(r.arrival);
                let decision = {
                    let tr = &rep.trackers[r.model as usize];
                    let keep = |x: usize| tr.enabled[x];
                    if wait > opts.serve.admission.overload_wait {
                        match r.sla {
                            Sla::MinEnergy => Admission::Shed,
                            Sla::LatencyBudget(b) => {
                                match fastest_filtered(&tr.points, keep) {
                                    None => Admission::Defer,
                                    Some(f) => {
                                        let eta = wait
                                            .saturating_add(tr.points[f].cycles)
                                            .saturating_add(opts.serve.launch_cycles);
                                        if eta <= b {
                                            Admission::Serve {
                                                point: f,
                                                degraded: true,
                                                sla_met: true,
                                            }
                                        } else {
                                            Admission::Shed
                                        }
                                    }
                                }
                            }
                        }
                    } else {
                        match dispatch_filtered(&tr.points, keep, r.sla) {
                            Some(d) => Admission::Serve {
                                point: d.point,
                                degraded: false,
                                sla_met: d.sla_met,
                            },
                            None => Admission::Defer,
                        }
                    }
                };
                match decision {
                    Admission::Serve { point, degraded, sla_met } => {
                        if rec.enabled() {
                            rec.virt(
                                rep.id,
                                r.arrival,
                                EventKind::Dispatch {
                                    req: r.id,
                                    point,
                                    label: rep.trackers[r.model as usize].points[point]
                                        .label
                                        .clone(),
                                    sla_met,
                                    degraded,
                                },
                            );
                        }
                        if degraded {
                            rep.retry.degraded_ids.insert(r.id);
                        }
                        serve_on(rep, Request { point, ..r }, &ctx, &mut cold_compiles)?;
                    }
                    Admission::Shed => {
                        rep.stats.registry_mut().inc(ctr::SHED);
                        rep.stats.registry_mut().inc(Tenant::from_sla(&r.sla).shed_counter());
                        rec.virt(rep.id, r.arrival, EventKind::AdmissionShed { req: r.id, wait });
                        shed_ids.push(r.id);
                    }
                    Admission::Defer => {
                        rec.virt(
                            rep.id,
                            r.arrival,
                            EventKind::DispatchDefer {
                                req: r.id,
                                enabled: rep.trackers[r.model as usize].enabled_count(),
                                total: rep.trackers[r.model as usize].points.len(),
                            },
                        );
                        let at = rep.trackers[r.model as usize].next_change_after(r.arrival);
                        rep.retry.schedule(
                            &r,
                            at,
                            opts.serve.max_retries,
                            &mut rep.stats,
                            rec,
                            rep.id,
                            r.arrival,
                        );
                    }
                }
            }
            // queue deadlines: flush every ripe batch on the replica
            2 => {
                let batches = replicas[j].batcher.due(now);
                for b in batches {
                    rec.virt(
                        replicas[j].id,
                        now,
                        EventKind::BatchFlush {
                            point: b.point,
                            size: b.requests.len(),
                            reason: FlushReason::Deadline,
                        },
                    );
                    handle_batch(&mut replicas[j], &b, &ctx, &mut cold_compiles)?;
                }
            }
            // in-flight completions (continuous batching only)
            _ => {
                tail_now = tail_now.max(now);
                let rep = &mut replicas[j];
                rep.advance_all(now, models, rec)?;
                if let Some(inf) = rep.inflight.take() {
                    complete_inflight(rep, inf, &ctx, now)?;
                }
            }
        }
        steal_pass(
            &mut replicas,
            now,
            &ctx,
            &mut cold_compiles,
            &mut steals,
            &mut stolen_requests,
        )?;
    }

    // fold per-replica stats into reports + cluster aggregates
    let mut tenants: BTreeMap<String, TenantRow> = BTreeMap::new();
    let mut model_rows: BTreeMap<(String, String), ModelTenantRow> = BTreeMap::new();
    for record in &trace.records {
        tenants
            .entry(record.tenant.clone())
            .or_insert_with(|| TenantRow {
                tenant: record.tenant.clone(),
                arrivals: 0,
                served: 0,
                sla_hits: 0,
                shed: 0,
                failed: 0,
            })
            .arrivals += 1;
        model_rows
            .entry((record.model.clone(), record.tenant.clone()))
            .or_insert_with(|| ModelTenantRow {
                model: record.model.clone(),
                tenant: record.tenant.clone(),
                arrivals: 0,
                served: 0,
                sla_hits: 0,
                shed: 0,
                failed: 0,
            })
            .arrivals += 1;
    }
    let tenant_of = |id: u64| trace.records.get(id as usize).map(|r| r.tenant.as_str());
    let model_key_of = |id: u64| {
        trace.records.get(id as usize).map(|r| (r.model.clone(), r.tenant.clone()))
    };
    let mut reports = Vec::with_capacity(n_replicas);
    let mut total_served = 0u64;
    let mut total_shed = 0u64;
    let mut total_failed = 0u64;
    let mut max_end = 0u64;
    for rep in replicas.iter_mut() {
        // per-replica caches start cold, so absolute cache counters
        // are this run's numbers (unlike run_serve's warm-cache deltas)
        let reg = rep.stats.registry_mut();
        reg.set(ctr::PLAN_HITS, rep.plans.hits);
        reg.set(ctr::PLAN_MISSES, rep.plans.misses);
        reg.set(ctr::PLAN_COMPILE_NS, rep.plans.compile_ns);
        reg.set(ctr::END_CYCLE, rep.device_free);
        max_end = max_end.max(rep.device_free);
        total_shed += rep.stats.registry().counter(ctr::SHED);
        total_failed += rep.stats.registry().counter(ctr::FAILED);
        for o in rep.stats.outcomes() {
            total_served += 1;
            if let Some(t) = tenant_of(o.id).and_then(|t| tenants.get_mut(t)) {
                t.served += 1;
                if o.sla_met {
                    t.sla_hits += 1;
                }
            }
            if let Some(m) = model_key_of(o.id).and_then(|k| model_rows.get_mut(&k)) {
                m.served += 1;
                if o.sla_met {
                    m.sla_hits += 1;
                }
            }
        }
        let model_labels: Vec<(String, Vec<String>)> = names
            .iter()
            .zip(&rep.trackers)
            .map(|(name, tracker)| {
                (name.clone(), tracker.points.iter().map(|p| p.label.clone()).collect())
            })
            .collect();
        reports.push(rep.stats.report_multi(
            &model_labels,
            &platform.name,
            pool.threads(),
            platform.f_clk_hz,
        ));
    }
    for id in &shed_ids {
        if let Some(t) = tenant_of(*id).and_then(|t| tenants.get_mut(t)) {
            t.shed += 1;
        }
        if let Some(m) = model_key_of(*id).and_then(|k| model_rows.get_mut(&k)) {
            m.shed += 1;
        }
    }
    for t in tenants.values_mut() {
        t.failed = t.arrivals.saturating_sub(t.served + t.shed);
    }
    for m in model_rows.values_mut() {
        m.failed = m.arrivals.saturating_sub(m.served + m.shed);
    }
    let accounted = total_served + total_shed + total_failed;
    if accounted != trace.len() as u64 {
        return Err(anyhow!(
            "cluster: accounting broke — {} served + {} shed + {} failed != {} trace \
             requests",
            total_served,
            total_shed,
            total_failed,
            trace.len()
        ));
    }
    let makespan_ms = max_end as f64 / platform.f_clk_hz * 1e3;
    let virtual_img_s = if max_end > 0 {
        total_served as f64 / (max_end as f64 / platform.f_clk_hz)
    } else {
        0.0
    };
    Ok(ClusterReport {
        model: names.join("+"),
        platform: platform.name.clone(),
        replicas: reports,
        dispatched,
        steals,
        stolen_requests,
        cold_compiles,
        total_requests: total_served,
        shed_requests: total_shed,
        failed_requests: total_failed,
        makespan_ms,
        virtual_img_s,
        tenants: tenants.into_values().collect(),
        model_rows: model_rows.into_values().collect(),
    })
}
