//! SLA-aware batched inference service over a cached Pareto frontier.
//!
//! The serving stack (docs/ARCHITECTURE.md §Serve):
//!
//! ```text
//!  sweep.rs     candidate mappings -> simulator + engine scores
//!               -> Pareto frontier -> versioned JSON cache
//!  dispatch.rs  request SLA -> cheapest *healthy* frontier mapping
//!  health.rs    fault-state mask + degraded re-mapping + admission
//!  batcher.rs   per-mapping queues -> dynamic batches -> LRU plan cache
//!  metrics.rs   per-request outcomes -> serve-report dashboard
//! ```
//!
//! The closed-loop driver (`run_serve`, crate-internal) pumps a seeded
//! synthetic request stream (arrivals, SLAs and inputs all derived
//! from one seed) through dispatch, the batcher and the quantized
//! engine, advancing a virtual clock in simulated cycles while the
//! engine executes each batch for real on the thread pool. Everything
//! except wall-clock throughput is deterministic for a given (model,
//! platform, seed, [`ServeOpts`]) — including fault handling: a
//! [`FaultPlan`] scripts unit failures on the same virtual timeline
//! (docs/ARCHITECTURE.md §Faults), so a faulted run replays exactly.
//!
//! Fault handling in one paragraph: batches whose unit dies mid-flight
//! are aborted and their requests re-enqueued with a virtual-cycle
//! backoff, bounded by [`ServeOpts::max_retries`] and then accounted
//! as failed; dispatch only ever sees mappings whose units are up
//! (dead-unit points are masked, water-filled re-mappings on the
//! degraded platform are appended per fault state); and an admission
//! controller ([`AdmissionCfg`]) sheds or degrades arrivals
//! predictably when the projected device wait exceeds its overload
//! threshold. The serve report carries the full accounting: every
//! synthesized request ends exactly one of served, shed, or failed.
//!
//! The workflow entry point is [`Session::serve`](crate::api::Session::serve):
//! the session owns the frontier, the thread pool and the LRU plan
//! cache, so repeated serve runs (and interleaved
//! [`Session::infer`](crate::api::Session::infer) calls) reuse compiled
//! plans instead of rebuilding them.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod cluster;
pub mod dispatch;
pub mod health;
pub mod metrics;
pub mod multi;
pub mod sweep;
pub mod trace;

pub use cluster::{ClusterOpts, ClusterReport, ModelTenantRow, TenantRow};
pub use dispatch::{dispatch, dispatch_filtered, Decision, Sla};
pub use health::AdmissionCfg;
pub use metrics::{ModelRow, ServeMetrics, ServeReport};
pub use multi::{ModelSet, ModelSlot};
pub use sweep::{FrontierPoint, SweepCfg};
pub use trace::{Trace, TraceError, TraceRecord};

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::data::synth::gen_sample;
use crate::hw::faults::FaultPlan;
use crate::hw::Platform;
use crate::model::Graph;
use crate::obs::{ctr, EventKind, FlushReason, Recorder};
use crate::quant::{KernelBackend, ParamSet, QuantNet, QuantPlan};
use crate::util::pool::ThreadPool;

use batcher::{Batch, Batcher, PlanCache, Request};
use dispatch::fastest_filtered;
use health::HealthTracker;
use metrics::{RequestOutcome, Tenant};

/// Closed-loop serve knobs (every field CLI-settable). The session
/// supplies model, platform, seed, threads and directories; these are
/// only the per-run stream/batching/robustness parameters.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Requests in the synthetic stream. `None` picks the default: 96,
    /// or 24 when the session was built with `smoke(true)`.
    pub n_requests: Option<usize>,
    /// Batcher flush threshold (1 = unbatched).
    pub max_batch: usize,
    /// Batcher wait bound, simulated cycles.
    pub max_wait: u64,
    /// Mean inter-arrival gap, simulated cycles.
    pub mean_gap: u64,
    /// Fixed per-batch launch overhead, simulated cycles (what dynamic
    /// batching amortizes on the virtual timeline).
    pub launch_cycles: u64,
    /// Scripted accelerator faults on the virtual timeline; `None`
    /// serves exactly as before faults existed.
    pub fault_plan: Option<FaultPlan>,
    /// Overload admission policy (default: never shed).
    pub admission: AdmissionCfg,
    /// Times one request may be re-enqueued (batch abort or no
    /// dispatchable mapping) before it is accounted as failed.
    pub max_retries: u32,
    /// Virtual-cycle backoff between a batch abort and the re-enqueue
    /// of its member requests.
    pub retry_backoff: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            n_requests: None,
            max_batch: 8,
            max_wait: 60_000,
            mean_gap: 20_000,
            launch_cycles: 10_000,
            fault_plan: None,
            admission: AdmissionCfg::default(),
            max_retries: 3,
            retry_backoff: 20_000,
        }
    }
}

/// Typed serve-loop failures — conditions the closed loop used to
/// panic on. They surface through `anyhow` with full context so a
/// service embedding the loop can match on them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The sweep produced (or the cache returned) zero frontier points.
    EmptyFrontier {
        /// Model being served.
        model: String,
        /// Platform being served on.
        platform: String,
    },
    /// Internal scheduling invariant broke: requests are pending but no
    /// event source (arrival, retry, queue deadline) can make progress.
    MissingDeadline {
        /// Requests stuck in the batcher when the invariant broke.
        pending: usize,
    },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::EmptyFrontier { model, platform } => write!(
                f,
                "serve: empty frontier for {model} on {platform} — run `sweep` or check \
                 the frontier cache"
            ),
            ServeError::MissingDeadline { pending } => write!(
                f,
                "serve: scheduling stalled with {pending} queued request(s) and no next \
                 event — this is a driver bug, please report it"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Report path for a (model, platform) serve run under `results_dir`.
pub fn report_path(results_dir: &Path, model: &str, platform: &str) -> PathBuf {
    results_dir.join(format!("serve_{model}_{platform}.json"))
}

/// Where a request's synthetic-input seed comes from. The single-
/// session loop seeds every request identically (the historical
/// behavior); trace replay carries a per-record seed, so the cluster
/// driver looks seeds up by request id.
pub(crate) enum SeedLookup<'a> {
    /// One seed for the whole stream.
    Uniform(u64),
    /// Per-request seeds indexed by request id, with a fallback for
    /// ids past the table (defensive — ids are always in range).
    PerRequest {
        /// `seeds[id]` is request `id`'s input seed.
        seeds: &'a [u64],
        /// Seed for out-of-table ids.
        fallback: u64,
    },
}

impl SeedLookup<'_> {
    pub(crate) fn seed_for(&self, id: u64) -> u64 {
        match self {
            SeedLookup::Uniform(s) => *s,
            SeedLookup::PerRequest { seeds, fallback } => {
                seeds.get(id as usize).copied().unwrap_or(*fallback)
            }
        }
    }
}

/// Retry-side bookkeeping, kept out of [`Request`] (which stays a
/// small `Copy` struct) in id-keyed tables.
struct RetryState {
    /// Re-enqueued requests, keyed by their retry cycle.
    q: BTreeMap<u64, Vec<Request>>,
    /// Times each request has been re-enqueued.
    attempts: BTreeMap<u64, u32>,
    /// Original arrival of retried requests (latency accounting spans
    /// aborts: queue time is measured from the *first* arrival).
    orig_arrival: BTreeMap<u64, u64>,
    /// Requests that received degraded service (retried, or admitted
    /// in degraded mode by the overload controller).
    degraded_ids: BTreeSet<u64>,
}

impl RetryState {
    fn new() -> Self {
        RetryState {
            q: BTreeMap::new(),
            attempts: BTreeMap::new(),
            orig_arrival: BTreeMap::new(),
            degraded_ids: BTreeSet::new(),
        }
    }

    /// Earliest scheduled retry cycle, if any.
    fn next_time(&self) -> Option<u64> {
        self.q.keys().next().copied()
    }

    /// Remove and return the requests scheduled at exactly `t`.
    fn pop_at(&mut self, t: u64) -> Vec<Request> {
        self.q.remove(&t).unwrap_or_default()
    }

    /// The request's first arrival (its own, unless it was retried).
    fn orig(&self, r: &Request) -> u64 {
        self.orig_arrival.get(&r.id).copied().unwrap_or(r.arrival)
    }

    /// Count one more attempt for `r` and either re-enqueue it at
    /// `retry_at` or — when attempts are exhausted or there is no
    /// useful retry time — account it as failed. `now` is the loop's
    /// current virtual cycle (the retry event is stamped there so the
    /// event stream stays monotone; the future cycle rides in the
    /// event payload).
    #[allow(clippy::too_many_arguments)]
    fn schedule(
        &mut self,
        r: &Request,
        retry_at: Option<u64>,
        max_retries: u32,
        stats: &mut ServeMetrics,
        rec: &Recorder,
        replica: u32,
        now: u64,
    ) {
        let att = self.attempts.entry(r.id).or_insert(0);
        *att += 1;
        self.orig_arrival.entry(r.id).or_insert(r.arrival);
        self.degraded_ids.insert(r.id);
        match retry_at {
            Some(t) if *att <= max_retries => {
                stats.registry_mut().inc(ctr::RETRIES);
                rec.virt(replica, now, EventKind::Retry { req: r.id, attempt: *att, retry_at: t });
                self.q
                    .entry(t)
                    .or_default()
                    .push(Request {
                        id: r.id,
                        arrival: t,
                        sla: r.sla,
                        model: r.model,
                        point: r.point,
                    });
            }
            _ => {
                stats.registry_mut().inc(ctr::FAILED);
                rec.virt(replica, now, EventKind::RetryExhausted { req: r.id, attempt: *att });
            }
        }
    }
}

/// Execute one flushed batch: check the fault timeline for a mid-batch
/// unit loss (abort + re-enqueue), otherwise compile-or-fetch the
/// plan, run the real engine on the pool, then advance the virtual
/// device clock and record every member request's outcome.
#[allow(clippy::too_many_arguments)]
fn exec_batch(
    batch: &Batch,
    graph: &Graph,
    params: &ParamSet<'_>,
    tracker: &HealthTracker,
    opts: &ServeOpts,
    seeds: &SeedLookup<'_>,
    pool: &ThreadPool,
    cache: &mut PlanCache,
    stats: &mut ServeMetrics,
    device_free: &mut u64,
    retry: &mut RetryState,
    backend: KernelBackend,
    rec: &Recorder,
    replica: u32,
) -> Result<()> {
    let fp = &tracker.points[batch.point];
    let platform = tracker.platform_for(batch.point);
    let bsz = batch.requests.len();
    let start = batch.flushed_at.max(*device_free);
    // derated units stretch the whole batch by the worst factor over
    // the units the mapping occupies (ARCHITECTURE.md §Faults); the
    // healthy factor 1.0 keeps the original integer arithmetic exactly
    let factor = tracker.exec_factor(batch.point, start);
    let per_img = if factor > 1.0 {
        (fp.cycles as f64 * factor).ceil() as u64
    } else {
        fp.cycles
    };
    let compute = opts.launch_cycles + per_img * bsz as u64;
    let done = start + compute;
    if let Some(abort_at) = tracker.abort_cycle(batch.point, start, done) {
        // the unit died under the batch: the work is lost, the device
        // pays an abort/cleanup cost, the members go back for retry
        stats.registry_mut().inc(ctr::BATCH_ABORTS);
        rec.virt(
            replica,
            batch.flushed_at,
            EventKind::BatchAbort { point: batch.point, at: abort_at },
        );
        *device_free = abort_at.saturating_add(opts.launch_cycles);
        let retry_at = abort_at.saturating_add(opts.retry_backoff.max(1));
        for r in &batch.requests {
            let at = batch.flushed_at;
            retry.schedule(r, Some(retry_at), opts.max_retries, stats, rec, replica, at);
        }
        return Ok(());
    }
    let (c, h, w) = graph.input_shape;
    let mut x = Vec::with_capacity(bsz * c * h * w);
    for r in &batch.requests {
        let cls = (r.id % graph.classes as u64) as u32;
        x.extend_from_slice(&gen_sample(seeds.seed_for(r.id), 1, r.id, cls, h, w));
    }
    let key =
        QuantPlan::cache_key(&graph.name, graph.spec_hash(), &platform.name, &fp.mapping, backend);
    // engine wall time excludes plan compilation: compile cost is
    // tracked separately by the cache (and reported as its own
    // dashboard line), so img/s measures steady-state compute only
    let compile_before = cache.compile_ns;
    let misses_before = cache.misses;
    let t0 = Instant::now();
    // at ObsLevel::Full the traced walk runs instead of the pooled one:
    // bit-identical numerics, but single-threaded and per-node timed
    let mut traced = None;
    {
        let net = cache.get_or_compile(key, &fp.mapping, || {
            QuantNet::compile_params_backend(params, graph, &fp.mapping, platform, backend)
        })?;
        if rec.full() {
            let t_ns = rec.now_ns();
            let (y, spans) = net.forward_traced(&x, bsz)?;
            std::hint::black_box(&y);
            traced = Some((net.isa().name(), t_ns, spans));
        } else {
            let y = net.forward_pool(&x, bsz, pool)?;
            std::hint::black_box(&y);
        }
    }
    let wall = t0.elapsed().as_nanos() as u64;
    let engine_ns = wall.saturating_sub(cache.compile_ns - compile_before);
    stats.record_batch(engine_ns);
    if rec.enabled() {
        let kind = if cache.misses > misses_before {
            EventKind::PlanCacheMiss { key }
        } else {
            EventKind::PlanCacheHit { key }
        };
        rec.virt(replica, batch.flushed_at, kind);
    }
    if let Some((isa, t_ns, spans)) = traced {
        rec.wall(
            replica,
            t_ns,
            EventKind::EngineRun {
                point: batch.point,
                batch: bsz,
                threads: pool.threads(),
                isa: isa.to_string(),
                dur_ns: engine_ns,
            },
        );
        for s in spans {
            rec.wall(
                replica,
                t_ns + s.start_ns,
                EventKind::KernelOp { node: s.node, kind: s.kind, algo: s.algo, dur_ns: s.dur_ns },
            );
        }
    }

    *device_free = done;
    if rec.enabled() {
        rec.virt(
            replica,
            start,
            EventKind::BatchExec {
                model: graph.name.clone(),
                point: batch.point,
                label: fp.label.clone(),
                start,
                done,
                size: bsz,
                per_img,
                launch: opts.launch_cycles,
                derated: factor > 1.0,
                energy_uj: fp.energy_uj,
                members: batch.requests.iter().map(|r| (r.id, retry.orig(r))).collect(),
            },
        );
    }
    for r in &batch.requests {
        let orig = retry.orig(r);
        let total = done - orig;
        let met = match r.sla {
            Sla::MinEnergy => true,
            Sla::LatencyBudget(b) => total <= b,
        };
        let degraded = tracker.is_degraded_point(batch.point)
            || factor > 1.0
            || retry.degraded_ids.contains(&r.id);
        stats.record(RequestOutcome {
            id: r.id,
            model: batch.model,
            point: batch.point,
            queue_cycles: start - orig,
            compute_cycles: compute,
            sla_met: met,
            batch_size: bsz,
            energy_uj: fp.energy_uj,
            degraded,
            tenant: Tenant::from_sla(&r.sla),
        });
    }
    Ok(())
}

/// Push one request through the batcher, narrating the queue life
/// cycle on the obs stream: batch-open on an empty per-point queue,
/// batch-join otherwise, and a size-triggered flush when this push
/// fills the batch. Behaviorally identical to `Batcher::push`.
pub(crate) fn push_traced(
    batcher: &mut Batcher,
    r: Request,
    rec: &Recorder,
    replica: u32,
) -> Option<Batch> {
    if rec.enabled() {
        let pending = batcher.pending_for(r.model, r.point);
        let kind = if pending == 0 {
            EventKind::BatchOpen { point: r.point }
        } else {
            EventKind::BatchJoin { point: r.point, pending: pending + 1 }
        };
        rec.virt(replica, r.arrival, kind);
    }
    let flushed = batcher.push(r);
    if let Some(b) = &flushed {
        rec.virt(
            replica,
            b.flushed_at,
            EventKind::BatchFlush {
                point: b.point,
                size: b.requests.len(),
                reason: FlushReason::Full,
            },
        );
    }
    flushed
}

/// Advance the fault tracker to `t`, emitting a fault-transition event
/// when the step changed which frontier points are dispatchable
/// (degraded re-mappings appended by the tracker also count).
pub(crate) fn advance_traced(
    tracker: &mut HealthTracker,
    t: u64,
    graph: &Graph,
    rec: &Recorder,
    replica: u32,
) -> Result<()> {
    if !rec.enabled() {
        return tracker.advance(t, graph);
    }
    let before = (tracker.enabled_count(), tracker.points.len());
    tracker.advance(t, graph)?;
    let after = (tracker.enabled_count(), tracker.points.len());
    if after != before {
        rec.virt(replica, t, EventKind::FaultTransition { enabled: after.0, total: after.1 });
    }
    Ok(())
}

/// What the admission/dispatch stage decided for one arrival.
enum Admission {
    /// Serve on this point. `degraded` marks overload service on the
    /// fastest point; `sla_met` is the dispatcher's planning-time
    /// verdict (the recorded outcome re-checks actual completion).
    Serve {
        /// Frontier point index the request was placed on.
        point: usize,
        /// Degraded (overload fast-path) service.
        degraded: bool,
        /// Planning-time SLA verdict from the dispatcher.
        sla_met: bool,
    },
    /// Shed under overload (reported, never silently dropped).
    Shed,
    /// No dispatchable point right now — retry at the next fault-state
    /// change (or fail when attempts run out).
    Defer,
}

/// Run the closed loop end to end over a pre-built frontier and a
/// caller-owned plan cache; plan-cache dashboard numbers are the
/// *deltas* of this run, so a warm session cache reports honestly.
/// Crate-internal: the public surface is
/// [`Session::serve`](crate::api::Session::serve).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_serve(
    graph: &Graph,
    platform: &Platform,
    params: &ParamSet<'_>,
    frontier: &[FrontierPoint],
    pool: &ThreadPool,
    plans: &mut PlanCache,
    opts: &ServeOpts,
    n_requests: usize,
    seed: u64,
    backend: KernelBackend,
    rec: &Recorder,
) -> Result<ServeReport> {
    if frontier.is_empty() {
        return Err(ServeError::EmptyFrontier {
            model: graph.name.clone(),
            platform: platform.name.clone(),
        }
        .into());
    }
    let resolved = match &opts.fault_plan {
        Some(plan) => Some(plan.resolve(platform)?),
        None => None,
    };
    let reqs = trace::Trace::synth(opts, n_requests, seed, frontier, &graph.name).to_requests();
    let seeds = SeedLookup::Uniform(seed);
    let mut tracker = HealthTracker::new(frontier, platform, resolved, graph);
    let mut batcher = Batcher::new(opts.max_batch, opts.max_wait);
    let mut stats = ServeMetrics::new();
    let mut retry = RetryState::new();
    let mut device_free = 0u64;
    let (hits0, misses0, compile0) = (plans.hits, plans.misses, plans.compile_ns);
    stats.registry_mut().set(ctr::FAULTS_INJECTED, tracker.n_events() as u64);

    // virtual-time event loop: interleave retries, arrivals and
    // queue-deadline flushes, earliest first (ties: retry, then
    // arrival, then deadline — arrival <= deadline preserves the
    // pre-fault ordering exactly); once arrivals and retries are
    // exhausted the tail drains immediately at the last event time
    // (the driver knows the stream ended — waiting out residual
    // deadlines would only inflate queue time, and a saturated
    // never-flush deadline must not reach the clock)
    let mut i = 0usize;
    let mut tail_now = reqs.last().map(|r| r.arrival).unwrap_or(0);
    while i < reqs.len() || batcher.pending() > 0 || retry.next_time().is_some() {
        let next_arrival = reqs.get(i).map(|r| r.arrival);
        let next_retry = retry.next_time();
        if next_arrival.is_none() && next_retry.is_none() {
            for b in batcher.drain(tail_now) {
                rec.virt(
                    0,
                    b.flushed_at,
                    EventKind::BatchFlush {
                        point: b.point,
                        size: b.requests.len(),
                        reason: FlushReason::Drain,
                    },
                );
                exec_batch(
                    &b,
                    graph,
                    params,
                    &tracker,
                    opts,
                    &seeds,
                    pool,
                    plans,
                    &mut stats,
                    &mut device_free,
                    &mut retry,
                    backend,
                    rec,
                    0,
                )?;
            }
            continue;
        }
        let candidates = [
            next_retry.map(|t| (t, 0u8)),
            next_arrival.map(|t| (t, 1u8)),
            batcher.next_deadline().map(|t| (t, 2u8)),
        ];
        let Some((now, source)) = candidates.iter().flatten().min().copied() else {
            // unreachable: an arrival or retry exists on this branch —
            // guarded instead of panicking inside the serve loop
            return Err(ServeError::MissingDeadline { pending: batcher.pending() }.into());
        };
        match source {
            // scheduled retries: re-dispatch under the current mask
            0 => {
                tail_now = tail_now.max(now);
                advance_traced(&mut tracker, now, graph, rec, 0)?;
                for r in retry.pop_at(now) {
                    let d = dispatch_filtered(&tracker.points, |j| tracker.enabled[j], r.sla);
                    match d {
                        Some(d) => {
                            if rec.enabled() {
                                rec.virt(
                                    0,
                                    now,
                                    EventKind::Dispatch {
                                        req: r.id,
                                        point: d.point,
                                        label: tracker.points[d.point].label.clone(),
                                        sla_met: d.sla_met,
                                        degraded: true,
                                    },
                                );
                            }
                            let queued = Request { point: d.point, ..r };
                            if let Some(b) = push_traced(&mut batcher, queued, rec, 0) {
                                exec_batch(
                                    &b,
                                    graph,
                                    params,
                                    &tracker,
                                    opts,
                                    &seeds,
                                    pool,
                                    plans,
                                    &mut stats,
                                    &mut device_free,
                                    &mut retry,
                                    backend,
                                    rec,
                                    0,
                                )?;
                            }
                        }
                        None => {
                            let at = tracker.next_change_after(now);
                            retry.schedule(&r, at, opts.max_retries, &mut stats, rec, 0, now);
                        }
                    }
                }
            }
            // arrivals: admission control, then masked dispatch
            1 => {
                let r = reqs[i];
                i += 1;
                advance_traced(&mut tracker, r.arrival, graph, rec, 0)?;
                let wait = device_free.saturating_sub(r.arrival);
                let keep = |j: usize| tracker.enabled[j];
                let decision = if wait > opts.admission.overload_wait {
                    match r.sla {
                        // min-energy requests are the lowest priority:
                        // under overload they shed first
                        Sla::MinEnergy => Admission::Shed,
                        Sla::LatencyBudget(b) => {
                            match fastest_filtered(&tracker.points, keep) {
                                None => Admission::Defer,
                                Some(f) => {
                                    let eta = wait
                                        .saturating_add(tracker.points[f].cycles)
                                        .saturating_add(opts.launch_cycles);
                                    if eta <= b {
                                        Admission::Serve { point: f, degraded: true, sla_met: true }
                                    } else {
                                        Admission::Shed
                                    }
                                }
                            }
                        }
                    }
                } else {
                    match dispatch_filtered(&tracker.points, keep, r.sla) {
                        Some(d) => {
                            Admission::Serve { point: d.point, degraded: false, sla_met: d.sla_met }
                        }
                        None => Admission::Defer,
                    }
                };
                match decision {
                    Admission::Serve { point, degraded, sla_met } => {
                        if rec.enabled() {
                            rec.virt(
                                0,
                                r.arrival,
                                EventKind::Dispatch {
                                    req: r.id,
                                    point,
                                    label: tracker.points[point].label.clone(),
                                    sla_met,
                                    degraded,
                                },
                            );
                        }
                        if degraded {
                            retry.degraded_ids.insert(r.id);
                        }
                        let queued = Request { point, ..r };
                        if let Some(b) = push_traced(&mut batcher, queued, rec, 0) {
                            exec_batch(
                                &b,
                                graph,
                                params,
                                &tracker,
                                opts,
                                &seeds,
                                pool,
                                plans,
                                &mut stats,
                                &mut device_free,
                                &mut retry,
                                backend,
                                rec,
                                0,
                            )?;
                        }
                    }
                    Admission::Shed => {
                        stats.registry_mut().inc(ctr::SHED);
                        stats.registry_mut().inc(Tenant::from_sla(&r.sla).shed_counter());
                        rec.virt(0, r.arrival, EventKind::AdmissionShed { req: r.id, wait });
                    }
                    Admission::Defer => {
                        log::debug!(
                            "serve: request {} has no dispatchable mapping at cycle {} \
                             ({}/{} points enabled)",
                            r.id,
                            r.arrival,
                            tracker.enabled_count(),
                            tracker.points.len()
                        );
                        rec.virt(
                            0,
                            r.arrival,
                            EventKind::DispatchDefer {
                                req: r.id,
                                enabled: tracker.enabled_count(),
                                total: tracker.points.len(),
                            },
                        );
                        let at = tracker.next_change_after(r.arrival);
                        retry.schedule(&r, at, opts.max_retries, &mut stats, rec, 0, r.arrival);
                    }
                }
            }
            // queue deadlines: flush every ripe batch
            _ => {
                for b in batcher.due(now) {
                    rec.virt(
                        0,
                        now,
                        EventKind::BatchFlush {
                            point: b.point,
                            size: b.requests.len(),
                            reason: FlushReason::Deadline,
                        },
                    );
                    exec_batch(
                        &b,
                        graph,
                        params,
                        &tracker,
                        opts,
                        &seeds,
                        pool,
                        plans,
                        &mut stats,
                        &mut device_free,
                        &mut retry,
                        backend,
                        rec,
                        0,
                    )?;
                }
            }
        }
    }

    // plan-cache dashboard numbers are this run's *deltas* (the
    // session cache may arrive warm); end_cycle is the makespan
    let reg = stats.registry_mut();
    reg.set(ctr::PLAN_HITS, plans.hits - hits0);
    reg.set(ctr::PLAN_MISSES, plans.misses - misses0);
    reg.set(ctr::PLAN_COMPILE_NS, plans.compile_ns - compile0);
    reg.set(ctr::END_CYCLE, device_free);
    let labels: Vec<String> = tracker.points.iter().map(|p| p.label.clone()).collect();
    Ok(stats.report(&graph.name, &platform.name, pool.threads(), &labels, platform.f_clk_hz))
}
