//! SLA-aware batched inference service over a cached Pareto frontier.
//!
//! The serving stack (docs/ARCHITECTURE.md §Serve):
//!
//! ```text
//!  sweep.rs     candidate mappings -> simulator + engine scores
//!               -> Pareto frontier -> versioned JSON cache
//!  dispatch.rs  request SLA -> cheapest frontier mapping in budget
//!  batcher.rs   per-mapping queues -> dynamic batches -> LRU plan cache
//!  metrics.rs   per-request outcomes -> serve-report dashboard
//! ```
//!
//! The closed-loop driver (`run_serve`, crate-internal) pumps a seeded
//! synthetic request stream (arrivals, SLAs and inputs all derived
//! from one seed) through dispatch, the batcher and the quantized
//! engine, advancing a virtual clock in simulated cycles while the
//! engine executes each batch for real on the thread pool. Everything
//! except wall-clock throughput is deterministic for a given (model,
//! platform, seed, [`ServeOpts`]).
//!
//! The workflow entry point is [`Session::serve`](crate::api::Session::serve):
//! the session owns the frontier, the thread pool and the LRU plan
//! cache, so repeated serve runs (and interleaved
//! [`Session::infer`](crate::api::Session::infer) calls) reuse compiled
//! plans instead of rebuilding them.

pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod sweep;

pub use dispatch::{dispatch, Decision, Sla};
pub use metrics::{ServeMetrics, ServeReport};
pub use sweep::{FrontierPoint, SweepCfg};

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::data::synth::gen_sample;
use crate::hw::Platform;
use crate::model::Graph;
use crate::quant::{ParamSet, QuantNet, QuantPlan};
use crate::util::pool::ThreadPool;
use crate::util::prng::Pcg32;

use batcher::{Batch, Batcher, PlanCache, Request};
use metrics::RequestOutcome;

/// Closed-loop serve knobs (every field CLI-settable). The session
/// supplies model, platform, seed, threads and directories; these are
/// only the per-run stream/batching parameters.
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Requests in the synthetic stream. `None` picks the default: 96,
    /// or 24 when the session was built with `smoke(true)`.
    pub n_requests: Option<usize>,
    /// Batcher flush threshold (1 = unbatched).
    pub max_batch: usize,
    /// Batcher wait bound, simulated cycles.
    pub max_wait: u64,
    /// Mean inter-arrival gap, simulated cycles.
    pub mean_gap: u64,
    /// Fixed per-batch launch overhead, simulated cycles (what dynamic
    /// batching amortizes on the virtual timeline).
    pub launch_cycles: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            n_requests: None,
            max_batch: 8,
            max_wait: 60_000,
            mean_gap: 20_000,
            launch_cycles: 10_000,
        }
    }
}

/// Report path for a (model, platform) serve run under `results_dir`.
pub fn report_path(results_dir: &Path, model: &str, platform: &str) -> PathBuf {
    results_dir.join(format!("serve_{model}_{platform}.json"))
}

/// Seeded synthetic request stream: arrivals with mean gap
/// `opts.mean_gap`, ~15% min-energy SLAs, the rest latency budgets
/// drawn around the frontier's own latency range (so some are
/// infeasible by construction and exercise the fallback path).
/// Dispatch decisions are folded in immediately — they depend only on
/// (frontier, SLA).
fn synth_requests(
    opts: &ServeOpts,
    n_requests: usize,
    seed: u64,
    frontier: &[FrontierPoint],
) -> Vec<Request> {
    let min_cyc = frontier.iter().map(|p| p.cycles).min().unwrap_or(0);
    let max_cyc = frontier.iter().map(|p| p.cycles).max().unwrap_or(0);
    let lo = (min_cyc as f64 * 0.8) as u64;
    let hi = (max_cyc + opts.launch_cycles) as f64 * 1.6;
    let mut rng = Pcg32::new(seed, 101);
    let mut t = 0u64;
    let mut reqs = Vec::with_capacity(n_requests);
    for id in 0..n_requests as u64 {
        t += 1 + (rng.next_f32() as f64 * 2.0 * opts.mean_gap as f64) as u64;
        let sla = if rng.next_f32() < 0.15 {
            Sla::MinEnergy
        } else {
            let u = rng.next_f32() as f64;
            Sla::LatencyBudget(lo + (u * (hi - lo as f64).max(1.0)) as u64)
        };
        let d = dispatch(frontier, sla).expect("non-empty frontier");
        reqs.push(Request { id, arrival: t, sla, point: d.point });
    }
    reqs
}

/// Execute one flushed batch: compile-or-fetch the plan, run the real
/// engine on the pool, then advance the virtual device clock and record
/// every member request's outcome.
#[allow(clippy::too_many_arguments)]
fn exec_batch(
    batch: &Batch,
    graph: &Graph,
    platform: &Platform,
    params: &ParamSet<'_>,
    frontier: &[FrontierPoint],
    opts: &ServeOpts,
    seed: u64,
    pool: &ThreadPool,
    cache: &mut PlanCache,
    stats: &mut ServeMetrics,
    device_free: &mut u64,
) -> Result<()> {
    let fp = &frontier[batch.point];
    let bsz = batch.requests.len();
    let (c, h, w) = graph.input_shape;
    let mut x = Vec::with_capacity(bsz * c * h * w);
    for r in &batch.requests {
        let cls = (r.id % graph.classes as u64) as u32;
        x.extend_from_slice(&gen_sample(seed, 1, r.id, cls, h, w));
    }
    let key = QuantPlan::cache_key(&graph.name, &platform.name, &fp.mapping);
    // engine wall time excludes plan compilation: compile cost is
    // tracked separately by the cache (and reported as its own
    // dashboard line), so img/s measures steady-state compute only
    let compile_before = cache.compile_ns;
    let t0 = Instant::now();
    {
        let net = cache.get_or_compile(key, &fp.mapping, || {
            QuantNet::compile_params(params, graph, &fp.mapping, platform)
        })?;
        let y = net.forward_pool(&x, bsz, pool)?;
        std::hint::black_box(&y);
    }
    let wall = t0.elapsed().as_nanos() as u64;
    stats.record_batch(wall.saturating_sub(cache.compile_ns - compile_before));

    let start = batch.flushed_at.max(*device_free);
    let compute = opts.launch_cycles + fp.cycles * bsz as u64;
    let done = start + compute;
    *device_free = done;
    for r in &batch.requests {
        let total = done - r.arrival;
        let met = match r.sla {
            Sla::MinEnergy => true,
            Sla::LatencyBudget(b) => total <= b,
        };
        stats.record(RequestOutcome {
            id: r.id,
            point: batch.point,
            queue_cycles: start - r.arrival,
            compute_cycles: compute,
            sla_met: met,
            batch_size: bsz,
            energy_uj: fp.energy_uj,
        });
    }
    Ok(())
}

/// Run the closed loop end to end over a pre-built frontier and a
/// caller-owned plan cache; plan-cache dashboard numbers are the
/// *deltas* of this run, so a warm session cache reports honestly.
/// Crate-internal: the public surface is
/// [`Session::serve`](crate::api::Session::serve).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_serve(
    graph: &Graph,
    platform: &Platform,
    params: &ParamSet<'_>,
    frontier: &[FrontierPoint],
    pool: &ThreadPool,
    plans: &mut PlanCache,
    opts: &ServeOpts,
    n_requests: usize,
    seed: u64,
) -> Result<ServeReport> {
    assert!(!frontier.is_empty(), "run_serve needs a non-empty frontier");
    let reqs = synth_requests(opts, n_requests, seed, frontier);
    let mut batcher = Batcher::new(opts.max_batch, opts.max_wait);
    let mut stats = ServeMetrics::new();
    let mut device_free = 0u64;
    let (hits0, misses0, compile0) = (plans.hits, plans.misses, plans.compile_ns);

    // virtual-time event loop: interleave arrivals with queue-deadline
    // flushes; once arrivals are exhausted the tail drains immediately
    // at the final arrival time (the driver knows the stream ended —
    // waiting out residual deadlines would only inflate queue time,
    // and a saturated never-flush deadline must not reach the clock)
    let mut i = 0usize;
    while i < reqs.len() || batcher.pending() > 0 {
        let next_arrival = reqs.get(i).map(|r| r.arrival);
        let next_deadline = batcher.next_deadline();
        let take_arrival = match (next_arrival, next_deadline) {
            (Some(a), Some(d)) => a <= d,
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_arrival {
            let r = reqs[i];
            i += 1;
            if let Some(b) = batcher.push(r) {
                exec_batch(&b, graph, platform, params, frontier, opts, seed, pool, plans,
                           &mut stats, &mut device_free)?;
            }
        } else if next_arrival.is_some() {
            let d = next_deadline.expect("pending queue has a deadline");
            for b in batcher.due(d) {
                exec_batch(&b, graph, platform, params, frontier, opts, seed, pool, plans,
                           &mut stats, &mut device_free)?;
            }
        } else {
            let now = reqs.last().map(|r| r.arrival).unwrap_or(0);
            for b in batcher.drain(now) {
                exec_batch(&b, graph, platform, params, frontier, opts, seed, pool, plans,
                           &mut stats, &mut device_free)?;
            }
        }
    }

    stats.plan_hits = plans.hits - hits0;
    stats.plan_misses = plans.misses - misses0;
    stats.plan_compile_ns = plans.compile_ns - compile0;
    stats.end_cycle = device_free;
    let labels: Vec<String> = frontier.iter().map(|p| p.label.clone()).collect();
    Ok(stats.report(&graph.name, &platform.name, pool.threads(), &labels, platform.f_clk_hz))
}
