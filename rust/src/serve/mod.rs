//! SLA-aware batched inference service over a cached Pareto frontier.
//!
//! The serving stack (docs/ARCHITECTURE.md §Serve):
//!
//! ```text
//!  sweep.rs     candidate mappings -> simulator + engine scores
//!               -> Pareto frontier -> versioned JSON cache
//!  dispatch.rs  request SLA -> cheapest frontier mapping in budget
//!  batcher.rs   per-mapping queues -> dynamic batches -> LRU plan cache
//!  metrics.rs   per-request outcomes -> serve-report dashboard
//! ```
//!
//! [`run_serve`] is the closed-loop driver behind the CLI `serve` verb:
//! it pumps a seeded synthetic request stream (arrivals, SLAs and
//! inputs all derived from one seed) through dispatch, the batcher and
//! the quantized engine, advancing a virtual clock in simulated cycles
//! while the engine executes each batch for real on the thread pool.
//! Everything except wall-clock throughput is deterministic for a given
//! (model, platform, seed, batching config).

pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod sweep;

pub use dispatch::{dispatch, Decision, Sla};
pub use metrics::{ServeMetrics, ServeReport};
pub use sweep::{FrontierPoint, SweepCfg};

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::synth::gen_sample;
use crate::hw::Platform;
use crate::model::Graph;
use crate::quant::{synth_params_on, ParamSet, QuantNet, QuantPlan};
use crate::util::pool::ThreadPool;
use crate::util::prng::Pcg32;

use batcher::{Batch, Batcher, PlanCache, Request};
use metrics::RequestOutcome;

/// Closed-loop serve configuration (all knobs CLI-settable).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    /// Model to serve (`tinycnn` by default: the closed loop runs the
    /// real engine per batch, and debug builds should stay snappy).
    pub model: String,
    /// Deployment platform.
    pub platform: Platform,
    /// Directory holding the frontier cache and the serve report.
    pub results_dir: PathBuf,
    /// Requests in the synthetic stream.
    pub n_requests: usize,
    /// Batcher flush threshold (1 = unbatched).
    pub max_batch: usize,
    /// Batcher wait bound, simulated cycles.
    pub max_wait: u64,
    /// Mean inter-arrival gap, simulated cycles.
    pub mean_gap: u64,
    /// Fixed per-batch launch overhead, simulated cycles (what dynamic
    /// batching amortizes on the virtual timeline).
    pub launch_cycles: u64,
    /// Worker threads (`None` = machine default).
    pub threads: Option<usize>,
    /// Seed for arrivals, SLAs, parameters and inputs — and for the
    /// sweep: `run_serve` forces `sweep.seed = seed` so the frontier is
    /// always scored under the same parameters it is served with.
    pub seed: u64,
    /// LRU plan-cache capacity.
    pub plan_cache_cap: usize,
    /// Sweep knobs used when the frontier cache is cold (`sweep.seed`
    /// is overridden by [`ServeCfg::seed`], see above).
    pub sweep: SweepCfg,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            model: "tinycnn".into(),
            platform: Platform::diana(),
            results_dir: PathBuf::from("results"),
            n_requests: 96,
            max_batch: 8,
            max_wait: 60_000,
            mean_gap: 20_000,
            launch_cycles: 10_000,
            threads: None,
            seed: 1234,
            plan_cache_cap: 4,
            sweep: SweepCfg::default(),
        }
    }
}

/// Report path for a (model, platform) serve run under `results_dir`.
pub fn report_path(results_dir: &Path, model: &str, platform: &str) -> PathBuf {
    results_dir.join(format!("serve_{model}_{platform}.json"))
}

/// Seeded synthetic request stream: arrivals with mean gap
/// `cfg.mean_gap`, ~15% min-energy SLAs, the rest latency budgets drawn
/// around the frontier's own latency range (so some are infeasible by
/// construction and exercise the fallback path). Dispatch decisions are
/// folded in immediately — they depend only on (frontier, SLA).
fn synth_requests(cfg: &ServeCfg, frontier: &[FrontierPoint]) -> Vec<Request> {
    let min_cyc = frontier.iter().map(|p| p.cycles).min().unwrap_or(0);
    let max_cyc = frontier.iter().map(|p| p.cycles).max().unwrap_or(0);
    let lo = (min_cyc as f64 * 0.8) as u64;
    let hi = (max_cyc + cfg.launch_cycles) as f64 * 1.6;
    let mut rng = Pcg32::new(cfg.seed, 101);
    let mut t = 0u64;
    let mut reqs = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests as u64 {
        t += 1 + (rng.next_f32() as f64 * 2.0 * cfg.mean_gap as f64) as u64;
        let sla = if rng.next_f32() < 0.15 {
            Sla::MinEnergy
        } else {
            let u = rng.next_f32() as f64;
            Sla::LatencyBudget(lo + (u * (hi - lo as f64).max(1.0)) as u64)
        };
        let d = dispatch(frontier, sla).expect("non-empty frontier");
        reqs.push(Request { id, arrival: t, sla, point: d.point });
    }
    reqs
}

/// Execute one flushed batch: compile-or-fetch the plan, run the real
/// engine on the pool, then advance the virtual device clock and record
/// every member request's outcome.
#[allow(clippy::too_many_arguments)]
fn exec_batch<'g>(
    batch: &Batch,
    graph: &'g Graph,
    params: &ParamSet<'_>,
    frontier: &[FrontierPoint],
    cfg: &ServeCfg,
    pool: &ThreadPool,
    cache: &mut PlanCache<'g>,
    stats: &mut ServeMetrics,
    device_free: &mut u64,
) -> Result<()> {
    let fp = &frontier[batch.point];
    let bsz = batch.requests.len();
    let (c, h, w) = graph.input_shape;
    let mut x = Vec::with_capacity(bsz * c * h * w);
    for r in &batch.requests {
        let cls = (r.id % graph.classes as u64) as u32;
        x.extend_from_slice(&gen_sample(cfg.seed, 1, r.id, cls, h, w));
    }
    let key = QuantPlan::cache_key(&graph.name, &cfg.platform.name, &fp.mapping);
    // engine wall time excludes plan compilation: compile cost is
    // tracked separately by the cache (and reported as its own
    // dashboard line), so img/s measures steady-state compute only
    let compile_before = cache.compile_ns;
    let t0 = Instant::now();
    {
        let net = cache.get_or_compile(key, &fp.mapping, || {
            QuantNet::compile_params(params, graph, &fp.mapping, &cfg.platform)
        })?;
        let y = net.forward_pool(&x, bsz, pool)?;
        std::hint::black_box(&y);
    }
    let wall = t0.elapsed().as_nanos() as u64;
    stats.record_batch(wall.saturating_sub(cache.compile_ns - compile_before));

    let start = batch.flushed_at.max(*device_free);
    let compute = cfg.launch_cycles + fp.cycles * bsz as u64;
    let done = start + compute;
    *device_free = done;
    for r in &batch.requests {
        let total = done - r.arrival;
        let met = match r.sla {
            Sla::MinEnergy => true,
            Sla::LatencyBudget(b) => total <= b,
        };
        stats.record(RequestOutcome {
            id: r.id,
            point: batch.point,
            queue_cycles: start - r.arrival,
            compute_cycles: compute,
            sla_met: met,
            batch_size: bsz,
            energy_uj: fp.energy_uj,
        });
    }
    Ok(())
}

/// Run the closed loop end to end and persist the report. Returns the
/// report so callers (CLI, tests, benches) can render or inspect it.
pub fn run_serve(cfg: &ServeCfg) -> Result<ServeReport> {
    let graph = crate::model::build(&cfg.model)?;
    let pool = match cfg.threads {
        Some(n) => ThreadPool::new(n),
        None => ThreadPool::with_default_size(),
    };
    // one seed rules the whole run: the frontier must be swept under
    // the same synthetic parameters the engine serves with, so the
    // sweep seed is always derived from cfg.seed, never set separately
    let sweep_cfg = SweepCfg { seed: cfg.seed, ..cfg.sweep };
    let (frontier, cache_hit) =
        sweep::load_or_sweep(&cfg.results_dir, &graph, &cfg.platform, &sweep_cfg, &pool)?;
    if frontier.is_empty() {
        return Err(anyhow!("empty frontier for {} on {}", graph.name, cfg.platform.name));
    }
    println!(
        "serve: frontier {} ({} points, {})",
        sweep::frontier_path(&cfg.results_dir, &graph.name, &cfg.platform.name).display(),
        frontier.len(),
        if cache_hit { "cache hit" } else { "swept fresh" }
    );

    let (names, values) = synth_params_on(&graph, &cfg.platform, cfg.seed);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let reqs = synth_requests(cfg, &frontier);
    let mut batcher = Batcher::new(cfg.max_batch, cfg.max_wait);
    let mut cache = PlanCache::new(cfg.plan_cache_cap);
    let mut stats = ServeMetrics::new();
    let mut device_free = 0u64;

    // virtual-time event loop: interleave arrivals with queue-deadline
    // flushes; once arrivals are exhausted the tail drains immediately
    // at the final arrival time (the driver knows the stream ended —
    // waiting out residual deadlines would only inflate queue time,
    // and a saturated never-flush deadline must not reach the clock)
    let mut i = 0usize;
    while i < reqs.len() || batcher.pending() > 0 {
        let next_arrival = reqs.get(i).map(|r| r.arrival);
        let next_deadline = batcher.next_deadline();
        let take_arrival = match (next_arrival, next_deadline) {
            (Some(a), Some(d)) => a <= d,
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_arrival {
            let r = reqs[i];
            i += 1;
            if let Some(b) = batcher.push(r) {
                exec_batch(&b, &graph, &params, &frontier, cfg, &pool, &mut cache,
                           &mut stats, &mut device_free)?;
            }
        } else if next_arrival.is_some() {
            let d = next_deadline.expect("pending queue has a deadline");
            for b in batcher.due(d) {
                exec_batch(&b, &graph, &params, &frontier, cfg, &pool, &mut cache,
                           &mut stats, &mut device_free)?;
            }
        } else {
            let now = reqs.last().map(|r| r.arrival).unwrap_or(0);
            for b in batcher.drain(now) {
                exec_batch(&b, &graph, &params, &frontier, cfg, &pool, &mut cache,
                           &mut stats, &mut device_free)?;
            }
        }
    }

    stats.plan_hits = cache.hits;
    stats.plan_misses = cache.misses;
    stats.plan_compile_ns = cache.compile_ns;
    stats.end_cycle = device_free;
    let labels: Vec<String> = frontier.iter().map(|p| p.label.clone()).collect();
    let report = stats.report(
        &graph.name,
        &cfg.platform.name,
        pool.threads(),
        &labels,
        cfg.platform.f_clk_hz,
    );
    let path = report_path(&cfg.results_dir, &graph.name, &cfg.platform.name);
    metrics::save_report(&path, &report)?;
    println!("serve: report written to {}", path.display());
    Ok(report)
}

/// CLI `sweep` verb: build (or load) the frontier and print it.
pub fn sweep_cmd(
    model: &str,
    platform: &Platform,
    results_dir: &Path,
    seed: u64,
    threads: Option<usize>,
) -> Result<()> {
    let graph = crate::model::build(model)?;
    let pool = match threads {
        Some(n) => ThreadPool::new(n),
        None => ThreadPool::with_default_size(),
    };
    let cfg = SweepCfg { seed, ..SweepCfg::default() };
    let path = sweep::frontier_path(results_dir, &graph.name, &platform.name);
    let (frontier, cache_hit) =
        sweep::load_or_sweep(results_dir, &graph, platform, &cfg, &pool)?;
    println!(
        "frontier for {} on {}: {} points ({} at {})",
        graph.name,
        platform.name,
        frontier.len(),
        if cache_hit { "cache hit" } else { "computed and cached" },
        path.display()
    );
    println!("{:<24} {:>12} {:>10} {:>10} {:>7}", "mapping", "cycles", "lat [ms]", "E [uJ]",
             "acc~");
    for p in &frontier {
        println!(
            "{:<24} {:>12} {:>10.4} {:>10.2} {:>7.3}",
            p.label, p.cycles, p.latency_ms, p.energy_uj, p.acc_proxy
        );
    }
    Ok(())
}

/// CLI `serve-report` verb: render the dashboard of a past serve run.
pub fn report_cmd(model: &str, platform: &str, results_dir: &Path) -> Result<()> {
    let path = report_path(results_dir, model, platform);
    let report = metrics::load_report(&path)
        .map_err(|e| anyhow!("{e:#}\nrun `odimo serve` first to produce the report"))?;
    println!("{}", report.dashboard());
    Ok(())
}
