//! Mapping-sweep engine: enumerate candidate mappings for a
//! (model, platform) pair, score each one on all three serving axes,
//! prune to the Pareto frontier, and cache the result.
//!
//! Candidates come from three families (paper Sec. IV-A baselines plus
//! a discretized search grid):
//!
//!   * **uniform** — all channels on each single accelerator
//!     (`all_<unit>`), plus the IO-8bit/Backbone-Ternary heuristic and
//!     the round-robin even split;
//!   * **min-cost** — the static water-filling / Pareto-DP optima under
//!     the latency and energy objectives ([`baselines::min_cost`]);
//!   * **blends** — discretized interpolations between all-on-unit-0
//!     (the accuracy-preserving extreme on DIANA-family platforms) and
//!     each min-cost optimum, which populate the middle of the
//!     accuracy-vs-cost trade-off the dispatcher selects from.
//!
//! Scoring: latency and energy come from the SoC simulator
//! ([`simulate`]); the **accuracy proxy** runs the quantized engine on
//! a seeded synthetic calibration batch and measures logit fidelity
//! against the float (quantization-free) reference plan — argmax
//! agreement blended with a normalized logit-error term — so mappings
//! that push more channels onto low-precision units score lower, the
//! same qualitative axis the paper's trained accuracy provides, without
//! needing trained artifacts on the serving host.
//!
//! The pruned frontier persists through [`store`] as a versioned JSON
//! cache keyed by (model, platform, schema version); a second sweep (or
//! a serve run) loads it back without recomputation, and a
//! schema-version mismatch is a clear error, never a misparse.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::coordinator::baselines::{self, CostObjective};
use crate::coordinator::Mapping;
use crate::data::synth::gen_sample;
use crate::exp::store;
use crate::hw::soc::{simulate, SocConfig};
use crate::hw::Platform;
use crate::model::Graph;
use crate::obs::{EventKind, Recorder};
use crate::quant::{synth_params_on, ParamSet, QuantNet, QuantPlan, Scratch};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;

/// Bump when the frontier cache layout changes; [`load_frontier`]
/// refuses files written under any other version. v2 added
/// `platform_hash` ([`Platform::spec_hash`]) so an edited platform
/// TOML invalidates the cache instead of silently reusing stale
/// points; v3 added the symmetric `model_hash`
/// ([`Graph::spec_hash`]) so an edited graph JSON re-sweeps too.
pub const FRONTIER_SCHEMA: u32 = 3;

/// One frontier entry: a mapping plus its three serving-axis scores.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    /// Candidate label (`all_dig`, `min_cost_lat`, `blend_en_50`, ...).
    pub label: String,
    /// The channel-to-accelerator assignment itself.
    pub mapping: Mapping,
    /// Simulated per-inference latency, cycles (the dispatch axis).
    pub cycles: u64,
    /// Simulated per-inference latency at the platform clock, ms.
    pub latency_ms: f64,
    /// Simulated per-inference energy, uJ.
    pub energy_uj: f64,
    /// Calibration-set accuracy proxy in [0, 1] (see module docs).
    pub acc_proxy: f64,
}

/// Sweep knobs (all deterministic given the seed).
#[derive(Clone, Copy, Debug)]
pub struct SweepCfg {
    /// Seed for the synthetic parameters and the calibration batch.
    pub seed: u64,
    /// Calibration images scored per candidate.
    pub calib: usize,
    /// Blend grid density: `blend_steps - 1` interior points between
    /// all-on-unit-0 and each min-cost optimum.
    pub blend_steps: usize,
}

impl Default for SweepCfg {
    fn default() -> Self {
        SweepCfg { seed: 1234, calib: 16, blend_steps: 4 }
    }
}

/// Enumerate the labelled candidate mappings for `platform` (module
/// docs list the three families). Duplicate assignments are dropped so
/// the frontier never carries two labels for one mapping.
pub fn candidate_mappings(
    graph: &Graph,
    platform: &Platform,
    blend_steps: usize,
) -> Vec<(String, Mapping)> {
    let n_acc = platform.n_acc();
    let mut out: Vec<(String, Mapping)> = Vec::new();
    let push = |label: String, m: Mapping, out: &mut Vec<(String, Mapping)>| {
        if !out.iter().any(|(_, q)| *q == m) {
            out.push((label, m));
        }
    };
    for (acc, spec) in platform.accelerators.iter().enumerate() {
        push(format!("all_{}", spec.name), Mapping::uniform(graph, acc), &mut out);
    }
    if n_acc >= 2 {
        push("io8_backbone_ternary".into(), baselines::io8_backbone_ternary(graph), &mut out);
        push("even_split".into(), baselines::even_split(graph, n_acc), &mut out);
    }
    for (objective, tag) in
        [(CostObjective::Latency, "lat"), (CostObjective::Energy, "en")]
    {
        push(
            format!("min_cost_{tag}"),
            baselines::min_cost(graph, platform, objective),
            &mut out,
        );
        // blends between all-on-unit-0 and the min-cost optimum: scale
        // the channels min-cost moved off unit 0 by alpha, unit 0
        // absorbs the remainder (conserves channels by construction)
        for s in 1..blend_steps {
            let alpha = s as f64 / blend_steps as f64;
            let mut m = Mapping::uniform(graph, 0);
            for node in graph.mappable() {
                let mc = baselines::layer_counts(platform, node, objective);
                let mut counts = vec![0usize; n_acc];
                let mut moved = 0usize;
                for (i, c) in counts.iter_mut().enumerate().skip(1) {
                    *c = (alpha * mc[i] as f64).round() as usize;
                    moved += *c;
                }
                counts[0] = node.cout - moved;
                m.set_layer_counts(&node.name, &counts);
            }
            push(format!("blend_{tag}_{}", (100.0 * alpha) as u32), m, &mut out);
        }
    }
    out
}

/// Accuracy proxy of one candidate: argmax agreement with the float
/// reference logits, blended 50/50 with a normalized logit-error
/// fidelity term so the proxy stays strictly ordered even when the
/// small calibration set agrees on every argmax.
fn acc_proxy(float_logits: &[f32], quant_logits: &[f32], batch: usize, classes: usize) -> f64 {
    let argmax = |v: &[f32]| -> usize {
        let mut best = 0usize;
        for (i, &x) in v.iter().enumerate() {
            if x > v[best] {
                best = i;
            }
        }
        best
    };
    let mut agree = 0usize;
    let mut err = 0f64;
    let mut mag = 0f64;
    for b in 0..batch {
        let f = &float_logits[b * classes..(b + 1) * classes];
        let q = &quant_logits[b * classes..(b + 1) * classes];
        if argmax(f) == argmax(q) {
            agree += 1;
        }
        for (a, c) in f.iter().zip(q) {
            err += (a - c).abs() as f64;
            mag += a.abs() as f64;
        }
    }
    let fidelity = 1.0 / (1.0 + err / mag.max(1e-9));
    0.5 * (agree as f64 / batch.max(1) as f64) + 0.5 * fidelity
}

/// Run the full sweep for (graph, platform): enumerate candidates,
/// score each on the simulator and the quantized engine, and return the
/// Pareto-pruned frontier sorted by latency ascending.
pub fn sweep_frontier(
    graph: &Graph,
    platform: &Platform,
    cfg: &SweepCfg,
    pool: &ThreadPool,
    rec: &Recorder,
) -> Result<Vec<FrontierPoint>> {
    let (c, h, w) = graph.input_shape;
    if c != 3 {
        return Err(anyhow!("{}: calibration generator needs 3-channel inputs", graph.name));
    }
    let calib = cfg.calib.max(1);
    let mut x = Vec::with_capacity(calib * c * h * w);
    for i in 0..calib {
        let cls = (i % graph.classes) as u32;
        x.extend_from_slice(&gen_sample(cfg.seed, 1, i as u64, cls, h, w));
    }
    let (names, values) = synth_params_on(graph, platform, cfg.seed);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    // float reference logits, computed once for every candidate. The
    // accuracy proxy is backend-invariant: every kernel backend is
    // bit-identical, so the frontier never needs a per-backend sweep.
    let float_plan = QuantPlan::compile_float(&params, graph)?;
    let mut ws = Scratch::new();
    let yf = float_plan.run_block(&x, calib, &mut ws, None);

    let n_acc = platform.n_acc();
    let soc_cfg = SocConfig::default();
    let mut points = Vec::new();
    for (label, mapping) in candidate_mappings(graph, platform, cfg.blend_steps) {
        mapping.validate(graph, n_acc)?;
        let rep = simulate(graph, &mapping.channel_split(n_acc), platform, soc_cfg);
        let net = QuantNet::compile_params(&params, graph, &mapping, platform)?;
        let yq = net.forward_pool(&x, calib, pool)?;
        let proxy = acc_proxy(&yf, &yq, calib, graph.classes);
        points.push(FrontierPoint {
            label,
            mapping,
            cycles: rep.total_cycles,
            latency_ms: rep.latency_ms,
            energy_uj: rep.energy_uj,
            acc_proxy: proxy,
        });
    }
    let kept = pareto_prune(&points);
    rec.note(
        log::Level::Info,
        EventKind::SweepDone {
            model: graph.name.clone(),
            platform: platform.name.clone(),
            candidates: points.len(),
            kept: kept.len(),
        },
    );
    let mut frontier: Vec<FrontierPoint> = Vec::with_capacity(kept.len());
    for i in kept {
        frontier.push(points[i].clone());
    }
    Ok(frontier)
}

/// `q` dominates `p`: no worse on latency, energy and accuracy, and not
/// the identical score tuple (identical tuples never dominate each
/// other, so duplicates survive pruning).
pub fn dominates(q: &FrontierPoint, p: &FrontierPoint) -> bool {
    q.cycles <= p.cycles
        && q.energy_uj <= p.energy_uj
        && q.acc_proxy >= p.acc_proxy
        && (q.cycles < p.cycles || q.energy_uj < p.energy_uj || q.acc_proxy > p.acc_proxy)
}

/// Max accuracy among staircase entries with energy <= `en` (the
/// staircase is sorted energy-ascending with accuracy ascending, so the
/// rightmost qualifying entry carries the maximum).
fn dominated_by_stairs(stairs: &[(f64, f64)], en: f64, acc: f64) -> bool {
    let pos = stairs.partition_point(|s| s.0 <= en);
    pos > 0 && stairs[pos - 1].1 >= acc
}

/// Insert a kept point into the (energy, accuracy) staircase,
/// discarding entries it makes redundant.
fn push_stair(stairs: &mut Vec<(f64, f64)>, en: f64, acc: f64) {
    let pos = stairs.partition_point(|s| s.0 <= en);
    if pos > 0 && stairs[pos - 1].1 >= acc {
        return; // an existing entry already covers this (en, acc)
    }
    let mut k = pos;
    while k < stairs.len() && stairs[k].1 <= acc {
        k += 1;
    }
    stairs.drain(pos..k);
    stairs.insert(pos, (en, acc));
}

/// Indices of the non-dominated points, sorted by (latency, energy)
/// ascending. One sorted sweep with an (energy, accuracy) staircase for
/// the strictly-faster prefix — `O(n log n)` plus pairwise checks only
/// inside equal-latency groups — differentially pinned against the
/// all-pairs O(n^2) oracle in `tests/serve_props.rs`.
pub fn pareto_prune(points: &[FrontierPoint]) -> Vec<usize> {
    let n = points.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        points[a]
            .cycles
            .cmp(&points[b].cycles)
            .then(points[a].energy_uj.total_cmp(&points[b].energy_uj))
            .then(points[b].acc_proxy.total_cmp(&points[a].acc_proxy))
    });
    let mut kept: Vec<usize> = Vec::new();
    let mut stairs: Vec<(f64, f64)> = Vec::new();
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j < n && points[order[j]].cycles == points[order[i]].cycles {
            j += 1;
        }
        // process one equal-latency group: staircase entries all have
        // strictly smaller latency, so a weak (energy, accuracy) match
        // there dominates; within the group dominance needs a strict
        // coordinate, checked pairwise against already-kept members
        // (any dominator sorts earlier under (energy asc, acc desc))
        let group_start = kept.len();
        for &gi in &order[i..j] {
            let p = &points[gi];
            let mut dom = dominated_by_stairs(&stairs, p.energy_uj, p.acc_proxy);
            if !dom {
                dom = kept[group_start..]
                    .iter()
                    .any(|&qi| dominates(&points[qi], p));
            }
            if !dom {
                kept.push(gi);
            }
        }
        for &gi in &kept[group_start..] {
            push_stair(&mut stairs, points[gi].energy_uj, points[gi].acc_proxy);
        }
        i = j;
    }
    kept
}

// ---- frontier cache ---------------------------------------------------

/// Cache path for a (model, platform) frontier under `results_dir`.
/// The schema version lives *inside* the file so stale caches are
/// detected, not silently shadowed by a new filename.
pub fn frontier_path(results_dir: &Path, model: &str, platform: &str) -> PathBuf {
    results_dir.join(format!("frontier_{model}_{platform}.json"))
}

fn point_to_json(p: &FrontierPoint) -> Json {
    Json::obj(vec![
        ("label", Json::str(p.label.clone())),
        ("cycles", Json::num(p.cycles as f64)),
        ("latency_ms", Json::num(p.latency_ms)),
        ("energy_uj", Json::num(p.energy_uj)),
        ("acc_proxy", Json::num(p.acc_proxy)),
        ("mapping", p.mapping.to_json()),
    ])
}

fn point_from_json(v: &Json) -> Result<FrontierPoint> {
    // req_f64 errors on missing *or* mistyped fields: a corrupted cache
    // must never decay into 0-cycle/0-energy points
    Ok(FrontierPoint {
        label: v.req("label")?.as_str().unwrap_or("").to_string(),
        cycles: v.req_f64("cycles")? as u64,
        latency_ms: v.req_f64("latency_ms")?,
        energy_uj: v.req_f64("energy_uj")?,
        acc_proxy: v.req_f64("acc_proxy")?,
        mapping: Mapping::from_json(v.req("mapping")?)?,
    })
}

/// Persist a frontier atomically under the versioned envelope. The
/// sweep configuration *and* both spec hashes — the resolved
/// platform's [`Platform::spec_hash`] and the graph's
/// [`Graph::spec_hash`] — are recorded alongside the points so a
/// later load under different knobs, an edited platform spec, or an
/// edited graph file is detected, not silently reused.
pub fn save_frontier(
    path: &Path,
    graph: &Graph,
    platform: &Platform,
    cfg: &SweepCfg,
    frontier: &[FrontierPoint],
) -> Result<()> {
    let payload = Json::obj(vec![
        ("model", Json::str(graph.name.clone())),
        ("platform", Json::str(platform.name.clone())),
        // strings: 64-bit values do not fit a JSON f64 exactly, and a
        // rounded seed would make the cache permanently miss
        ("platform_hash", Json::str(format!("{:016x}", platform.spec_hash()))),
        ("model_hash", Json::str(format!("{:016x}", graph.spec_hash()))),
        ("sweep_seed", Json::str(cfg.seed.to_string())),
        ("sweep_calib", Json::num(cfg.calib as f64)),
        ("sweep_blend_steps", Json::num(cfg.blend_steps as f64)),
        ("points", Json::Arr(frontier.iter().map(point_to_json).collect())),
    ]);
    store::save_versioned(path, "frontier", FRONTIER_SCHEMA, payload)
}

/// A loaded frontier cache file: the points plus the sweep knobs and
/// platform-spec hash they were computed under.
#[derive(Debug)]
pub struct CachedFrontier {
    /// The frontier points, latency-ascending.
    pub points: Vec<FrontierPoint>,
    /// The [`SweepCfg`] the cache was swept with.
    pub swept_with: SweepCfg,
    /// [`Platform::spec_hash`] of the platform the cache was swept on.
    pub platform_hash: u64,
    /// [`Graph::spec_hash`] of the graph the cache was swept for.
    pub model_hash: u64,
}

/// Load a cached frontier, erroring clearly on kind/schema mismatch or
/// a (model, platform) key that does not match the request.
pub fn load_frontier(path: &Path, model: &str, platform: &str) -> Result<CachedFrontier> {
    let payload = store::load_versioned(path, "frontier", FRONTIER_SCHEMA)?;
    let got_model = payload.req("model")?.as_str().unwrap_or("");
    let got_platform = payload.req("platform")?.as_str().unwrap_or("");
    if got_model != model || got_platform != platform {
        return Err(anyhow!(
            "{}: cached for ({got_model}, {got_platform}), requested ({model}, {platform})",
            path.display()
        ));
    }
    let hash_hex = payload.req("platform_hash")?.as_str().unwrap_or("").to_string();
    let platform_hash = u64::from_str_radix(&hash_hex, 16)
        .map_err(|_| anyhow!("{}: bad platform_hash '{hash_hex}'", path.display()))?;
    let mh_hex = payload.req("model_hash")?.as_str().unwrap_or("").to_string();
    let model_hash = u64::from_str_radix(&mh_hex, 16)
        .map_err(|_| anyhow!("{}: bad model_hash '{mh_hex}'", path.display()))?;
    let seed_str = payload.req("sweep_seed")?.as_str().unwrap_or("").to_string();
    let seed = seed_str
        .parse::<u64>()
        .map_err(|_| anyhow!("{}: bad sweep_seed '{seed_str}'", path.display()))?;
    let swept_with = SweepCfg {
        seed,
        calib: payload.req_f64("sweep_calib")? as usize,
        blend_steps: payload.req_f64("sweep_blend_steps")? as usize,
    };
    let points = payload
        .req("points")?
        .as_arr()
        .ok_or_else(|| anyhow!("frontier points must be a json array"))?
        .iter()
        .map(point_from_json)
        .collect::<Result<Vec<FrontierPoint>>>()?;
    Ok(CachedFrontier { points, swept_with, platform_hash, model_hash })
}

/// Load the cached frontier if present, swept under the *same*
/// [`SweepCfg`], and computed on a platform whose
/// [`Platform::spec_hash`] still matches (returning
/// `cache_hit = true`); on a knob or spec mismatch the cache is
/// re-swept and overwritten — never silently reused — so serve runs
/// stay deterministic in (model, platform spec, seed, config) and an
/// edited platform TOML invalidates `frontier_<model>_<platform>.json`.
pub fn load_or_sweep(
    results_dir: &Path,
    graph: &Graph,
    platform: &Platform,
    cfg: &SweepCfg,
    pool: &ThreadPool,
    rec: &Recorder,
) -> Result<(Vec<FrontierPoint>, bool)> {
    let path = frontier_path(results_dir, &graph.name, &platform.name);
    // a cache written under a *known older* schema is stale, not an
    // error: upgrading must not require hand-deleting regenerable
    // files. Unknown/newer versions (and corruption) still refuse —
    // they could mean a downgraded binary or a tampered file.
    if path.exists() && written_under_older_schema(&path) {
        rec.note(
            log::Level::Info,
            EventKind::FrontierCacheStale {
                path: path.display().to_string(),
                reason: format!("predates schema v{FRONTIER_SCHEMA}"),
            },
        );
    } else if path.exists() {
        let cached = load_frontier(&path, &graph.name, &platform.name)?;
        let sw = &cached.swept_with;
        let knobs_match =
            sw.seed == cfg.seed && sw.calib == cfg.calib && sw.blend_steps == cfg.blend_steps;
        if knobs_match
            && cached.platform_hash == platform.spec_hash()
            && cached.model_hash == graph.spec_hash()
        {
            for p in &cached.points {
                p.mapping.validate(graph, platform.n_acc())?;
            }
            rec.note(
                log::Level::Info,
                EventKind::FrontierCacheHit { path: path.display().to_string() },
            );
            return Ok((cached.points, true));
        }
        let reason = if !knobs_match {
            format!(
                "swept under different knobs (seed {} calib {} blends {})",
                sw.seed, sw.calib, sw.blend_steps
            )
        } else if cached.platform_hash != platform.spec_hash() {
            format!(
                "platform spec changed (cached {:016x}, resolved {:016x})",
                cached.platform_hash,
                platform.spec_hash()
            )
        } else {
            format!(
                "model spec changed (cached {:016x}, loaded {:016x})",
                cached.model_hash,
                graph.spec_hash()
            )
        };
        rec.note(
            log::Level::Info,
            EventKind::FrontierCacheStale { path: path.display().to_string(), reason },
        );
    }
    let frontier = sweep_frontier(graph, platform, cfg, pool, rec)?;
    save_frontier(&path, graph, platform, cfg, &frontier)?;
    rec.note(
        log::Level::Info,
        EventKind::FrontierCacheWritten { path: path.display().to_string() },
    );
    Ok((frontier, false))
}

/// True when `path` is a readable frontier envelope whose
/// `schema_version` is a *lower* known version than
/// [`FRONTIER_SCHEMA`] — the overwrite-on-upgrade case. Anything else
/// (newer version, wrong kind, unreadable) returns false so the
/// strict loader reports it.
fn written_under_older_schema(path: &Path) -> bool {
    let Ok(text) = std::fs::read_to_string(path) else {
        return false;
    };
    let Ok(doc) = crate::util::json::parse(&text) else {
        return false;
    };
    if doc.req("kind").ok().and_then(|k| k.as_str()) != Some("frontier") {
        return false;
    }
    match doc.req("schema_version").ok().and_then(|v| v.as_usize()) {
        Some(v) => (v as u32) < FRONTIER_SCHEMA,
        None => false,
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::model::tinycnn;
    use std::collections::BTreeMap;

    fn pt(cycles: u64, energy_uj: f64, acc: f64) -> FrontierPoint {
        FrontierPoint {
            label: String::new(),
            mapping: Mapping { assign: BTreeMap::new() },
            cycles,
            latency_ms: cycles as f64 * 1e-6,
            energy_uj,
            acc_proxy: acc,
        }
    }

    #[test]
    fn prune_keeps_only_nondominated() {
        let pts = vec![
            pt(100, 10.0, 0.9),
            pt(100, 12.0, 0.8), // dominated by [0]
            pt(200, 5.0, 0.7),
            pt(300, 5.0, 0.7), // dominated by [2]
            pt(300, 4.0, 0.95),
        ];
        let kept = pareto_prune(&pts);
        assert_eq!(kept, vec![0, 2, 4]);
    }

    #[test]
    fn prune_keeps_identical_duplicates() {
        let pts = vec![pt(100, 10.0, 0.9), pt(100, 10.0, 0.9)];
        let kept = pareto_prune(&pts);
        assert_eq!(kept.len(), 2, "identical points never dominate each other");
    }

    #[test]
    fn candidates_are_valid_and_distinct() {
        let g = tinycnn();
        for p in [Platform::diana(), Platform::mpsoc4()] {
            let cands = candidate_mappings(&g, &p, 4);
            assert!(cands.len() >= p.n_acc() + 2, "{}: {} candidates", p.name, cands.len());
            for (label, m) in &cands {
                m.validate(&g, p.n_acc()).unwrap_or_else(|e| panic!("{label}: {e}"));
            }
            for (i, (_, a)) in cands.iter().enumerate() {
                for (_, b) in &cands[i + 1..] {
                    assert_ne!(a, b, "duplicate candidate mapping on {}", p.name);
                }
            }
        }
    }

    #[test]
    fn frontier_cache_roundtrip() {
        let g = tinycnn();
        let p = Platform::diana();
        let pool = ThreadPool::new(2);
        let cfg = SweepCfg { seed: 11, calib: 4, blend_steps: 2 };
        let dir = std::env::temp_dir().join("odimo_sweep_roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let (a, hit_a) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &Recorder::disabled()).unwrap();
        assert!(!hit_a);
        let (b, hit_b) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &Recorder::disabled()).unwrap();
        assert!(hit_b, "second load must be a cache hit");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.cycles, y.cycles);
            assert_eq!(x.mapping, y.mapping);
            assert!((x.acc_proxy - y.acc_proxy).abs() < 1e-12);
        }
    }

    #[test]
    fn wrong_platform_key_rejected() {
        let g = tinycnn();
        let p = Platform::diana();
        let dir = std::env::temp_dir().join("odimo_sweep_wrong_key");
        let _ = std::fs::remove_dir_all(&dir);
        let path = frontier_path(&dir, &g.name, &p.name);
        save_frontier(&path, &g, &p, &SweepCfg::default(), &[]).unwrap();
        let e = load_frontier(&path, &g.name, "mpsoc4").unwrap_err().to_string();
        assert!(e.contains("mpsoc4"), "{e}");
    }

    #[test]
    fn older_schema_cache_is_stale_not_fatal() {
        // upgrade path: a v1-era cache re-sweeps; a *newer*/unknown
        // version still errors (see serve_props schema-tamper test)
        let g = tinycnn();
        let p = Platform::diana();
        let pool = ThreadPool::new(2);
        let cfg = SweepCfg { seed: 21, calib: 4, blend_steps: 2 };
        let dir = std::env::temp_dir().join("odimo_sweep_old_schema");
        let _ = std::fs::remove_dir_all(&dir);
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &Recorder::disabled()).unwrap();
        assert!(!hit);
        let path = frontier_path(&dir, &g.name, &p.name);
        let text = std::fs::read_to_string(&path).unwrap();
        let old = text.replace("\"schema_version\":3", "\"schema_version\":2");
        assert_ne!(text, old);
        std::fs::write(&path, old).unwrap();
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &Recorder::disabled()).unwrap();
        assert!(!hit, "older schema must re-sweep, not error or reuse");
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &Recorder::disabled()).unwrap();
        assert!(hit, "rewritten cache hits again");
    }

    #[test]
    fn edited_platform_spec_invalidates_cache() {
        // the ROADMAP "frontier refresh" case: a platform whose TOML
        // was edited keeps its name, so the spec hash must catch it
        let g = tinycnn();
        let pool = ThreadPool::new(2);
        let cfg = SweepCfg { seed: 5, calib: 4, blend_steps: 2 };
        let dir = std::env::temp_dir().join("odimo_sweep_platform_edit");
        let _ = std::fs::remove_dir_all(&dir);
        let off = Recorder::disabled();
        let (_, hit) = load_or_sweep(&dir, &g, &Platform::diana(), &cfg, &pool, &off).unwrap();
        assert!(!hit);
        let mut edited = Platform::diana();
        edited.accelerators[0].p_act_mw += 1.0;
        let (_, hit) = load_or_sweep(&dir, &g, &edited, &cfg, &pool, &off).unwrap();
        assert!(!hit, "edited platform spec must re-sweep, not reuse");
        // the rewritten cache now hits under the edited spec...
        let (_, hit) = load_or_sweep(&dir, &g, &edited, &cfg, &pool, &off).unwrap();
        assert!(hit);
        // ...and misses again if the edit is reverted
        let (_, hit) = load_or_sweep(&dir, &g, &Platform::diana(), &cfg, &pool, &off).unwrap();
        assert!(!hit, "reverting the spec is also a cache-key change");
    }

    #[test]
    fn edited_model_spec_invalidates_cache() {
        // the import-side twin of the platform-edit test: a graph JSON
        // whose structure was edited keeps its model name, so
        // `model_hash` must catch it and re-sweep
        let g = tinycnn();
        let pool = ThreadPool::new(2);
        let cfg = SweepCfg { seed: 6, calib: 4, blend_steps: 2 };
        let dir = std::env::temp_dir().join("odimo_sweep_model_edit");
        let _ = std::fs::remove_dir_all(&dir);
        let off = Recorder::disabled();
        let p = Platform::diana();
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &off).unwrap();
        assert!(!hit);
        // same model name, one conv widened: structurally a new graph
        let mut nodes = g.nodes.clone();
        nodes[2].cout = 24;
        nodes[3].cin = 24;
        nodes[3].cout = 24;
        nodes[4].cin = 24;
        nodes[4].cout = 24;
        nodes[5].cin = 24;
        nodes[5].cout = 24;
        nodes[6].cin = 24;
        let edited = crate::model::Graph::new(
            g.name.clone(),
            g.input_shape,
            g.classes,
            g.train_batch,
            g.eval_batch,
            nodes,
        );
        assert_ne!(edited.spec_hash(), g.spec_hash());
        let (_, hit) = load_or_sweep(&dir, &edited, &p, &cfg, &pool, &off).unwrap();
        assert!(!hit, "edited model spec must re-sweep, not reuse");
        // the rewritten cache hits under the edited graph...
        let (_, hit) = load_or_sweep(&dir, &edited, &p, &cfg, &pool, &off).unwrap();
        assert!(hit);
        // ...and misses again for the original
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg, &pool, &off).unwrap();
        assert!(!hit, "reverting the graph is also a cache-key change");
    }

    #[test]
    fn different_sweep_knobs_resweep_instead_of_reusing() {
        let g = tinycnn();
        let p = Platform::diana();
        let pool = ThreadPool::new(2);
        let dir = std::env::temp_dir().join("odimo_sweep_knob_mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg_a = SweepCfg { seed: 1, calib: 4, blend_steps: 2 };
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg_a, &pool, &Recorder::disabled()).unwrap();
        assert!(!hit);
        // a different seed must never silently reuse the seed-1 cache
        let cfg_b = SweepCfg { seed: 2, calib: 4, blend_steps: 2 };
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg_b, &pool, &Recorder::disabled()).unwrap();
        assert!(!hit, "knob mismatch must re-sweep");
        // the overwritten cache now hits under the new knobs
        let (_, hit) = load_or_sweep(&dir, &g, &p, &cfg_b, &pool, &Recorder::disabled()).unwrap();
        assert!(hit);
    }
}
