//! Multi-model serving set: owned per-model state behind the cluster
//! driver's borrowed [`ClusterModel`](super::cluster::ClusterModel)
//! views (docs/ARCHITECTURE.md §Import & Multi-model).
//!
//! A [`ModelSet`] resolves each model *spec* — a built-in name from
//! [`ALL_MODELS`] or a path to an `odimo_graph` JSON file — into a
//! [`ModelSlot`]: the loaded [`Graph`], its seeded synthetic parameter
//! snapshot (the same `synth_params_on` derivation the single-model
//! session uses, so a one-model set serves bit-identically to
//! [`Session::serve`](crate::api::Session::serve)), and its Pareto
//! frontier swept lazily per model through the invalidation-aware disk
//! cache. Slot order defines the request-routing index space: slot `i`
//! is `Request::model == i`, and trace records route to slots by graph
//! name.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::hw::Platform;
use crate::model::{self, Graph, ALL_MODELS};
use crate::obs::Recorder;
use crate::quant::{synth_params_on, ParamSet};
use crate::serve::sweep::{self, FrontierPoint, SweepCfg};
use crate::util::pool::ThreadPool;

use super::cluster::ClusterModel;
use super::{ServeOpts, Trace};

/// One resolved model: everything the cluster driver borrows per model,
/// owned here so the borrows in [`ClusterModel`] have a home.
#[derive(Debug)]
pub struct ModelSlot {
    /// The loaded graph (built-in or imported).
    pub graph: Graph,
    /// Synthetic parameter names (`ParamSet` key side).
    pub param_names: Vec<String>,
    /// Synthetic parameter values (`ParamSet` value side).
    pub param_values: Vec<Vec<f32>>,
    /// Pareto frontier on the serving platform, latency-ascending.
    pub frontier: Vec<FrontierPoint>,
    /// Whether the frontier came from a valid disk cache.
    pub frontier_cache_hit: bool,
}

/// The ordered serving set. Construction resolves, validates and
/// sweeps every model once; serving borrows the slots read-only.
#[derive(Debug)]
pub struct ModelSet {
    slots: Vec<ModelSlot>,
}

/// Resolve one model spec: a built-in name from [`ALL_MODELS`], or a
/// path to an imported `odimo_graph` JSON file (anything containing a
/// path separator or ending in `.json`).
pub fn resolve_graph(spec: &str) -> Result<Graph> {
    if ALL_MODELS.contains(&spec) {
        return model::build(spec);
    }
    if spec.ends_with(".json") || spec.contains('/') || spec.contains('\\') {
        return Graph::from_json_file(Path::new(spec));
    }
    Err(anyhow!(
        "unknown model '{spec}' (choose from {ALL_MODELS:?} or pass a graph .json path)"
    ))
}

impl ModelSet {
    /// Resolve `specs` in order and sweep each model's frontier on
    /// `platform` (through the disk cache under `results_dir`). Every
    /// parameter snapshot derives from the same `seed` the single-model
    /// session uses. Duplicate graph names are rejected: trace records
    /// route by name, so the mapping must be injective.
    pub fn load(
        specs: &[String],
        platform: &Platform,
        results_dir: &Path,
        sweep_cfg: &SweepCfg,
        pool: &ThreadPool,
        rec: &Recorder,
    ) -> Result<ModelSet> {
        if specs.is_empty() {
            return Err(anyhow!("the serving set needs at least one model"));
        }
        let mut slots: Vec<ModelSlot> = Vec::with_capacity(specs.len());
        for spec in specs {
            let graph = resolve_graph(spec)?;
            if slots.iter().any(|s| s.graph.name == graph.name) {
                return Err(anyhow!(
                    "duplicate model '{}' in the serving set (trace records route by \
                     name, so each model may appear once)",
                    graph.name
                ));
            }
            let (param_names, param_values) = synth_params_on(&graph, platform, sweep_cfg.seed);
            let (frontier, frontier_cache_hit) =
                sweep::load_or_sweep(results_dir, &graph, platform, sweep_cfg, pool, rec)?;
            if frontier.is_empty() {
                return Err(anyhow!("empty frontier for {} on {}", graph.name, platform.name));
            }
            slots.push(ModelSlot {
                graph,
                param_names,
                param_values,
                frontier,
                frontier_cache_hit,
            });
        }
        Ok(ModelSet { slots })
    }

    /// The resolved slots in routing order.
    pub fn slots(&self) -> &[ModelSlot] {
        &self.slots
    }

    /// Graph names in routing order (slot `i` serves `Request::model
    /// == i`).
    pub fn names(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.graph.name.clone()).collect()
    }

    /// Models in the set.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the set is empty (never true for a loaded set).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Borrow every slot's parameters as `ParamSet` views, in slot
    /// order — the caller keeps the vector alive for the duration of
    /// the run and zips it into [`ModelSet::cluster_models`].
    pub(crate) fn param_sets(&self) -> Vec<ParamSet<'_>> {
        self.slots
            .iter()
            .map(|s| ParamSet::new(s.param_names.iter().map(|n| n.as_str()), &s.param_values))
            .collect()
    }

    /// The borrowed per-model views the cluster driver consumes.
    /// `params` must be this set's [`ModelSet::param_sets`] (one entry
    /// per slot, same order).
    pub(crate) fn cluster_models<'a>(
        &'a self,
        params: &'a [ParamSet<'a>],
    ) -> Vec<ClusterModel<'a>> {
        debug_assert_eq!(params.len(), self.slots.len());
        self.slots
            .iter()
            .zip(params)
            .map(|(s, p)| ClusterModel { graph: &s.graph, params: p, frontier: &s.frontier })
            .collect()
    }
}

/// Synthesize a mixed multi-model trace: `n_per_model` requests per
/// slot via [`Trace::synth`] (slot `i` draws from `seed + i`, so the
/// per-model streams are independent), merged by arrival cycle with
/// ties broken by slot order. With one model this is byte-identical to
/// `Trace::synth(opts, n, seed, frontier, name)` — the single-model
/// pin the serve plane's digest tests rely on.
pub fn synth_mixed(opts: &ServeOpts, n_per_model: usize, seed: u64, set: &ModelSet) -> Trace {
    let mut tagged: Vec<(u64, usize, usize, super::TraceRecord)> = Vec::new();
    for (mi, slot) in set.slots().iter().enumerate() {
        let t = Trace::synth(
            opts,
            n_per_model,
            seed.wrapping_add(mi as u64),
            &slot.frontier,
            &slot.graph.name,
        );
        for (ri, rec) in t.records.into_iter().enumerate() {
            tagged.push((rec.arrival_cycle, mi, ri, rec));
        }
    }
    tagged.sort_by(|a, b| (a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)));
    Trace { records: tagged.into_iter().map(|(_, _, _, r)| r).collect() }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn resolve_rejects_unknown_bare_names() {
        let e = resolve_graph("not_a_model").unwrap_err().to_string();
        assert!(e.contains("unknown model"), "{e}");
        assert!(e.contains("graph .json path"), "{e}");
    }

    #[test]
    fn resolve_builds_every_builtin() {
        for name in ALL_MODELS {
            let g = resolve_graph(name).unwrap();
            assert_eq!(&g.name, name);
        }
    }

    #[test]
    fn load_rejects_duplicates_and_empty_sets() {
        let platform = Platform::diana();
        let pool = ThreadPool::new(1);
        let dir = std::env::temp_dir().join("odimo_multi_dup");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SweepCfg { seed: 7, calib: 4, blend_steps: 2 };
        let rec = Recorder::disabled();
        let e = ModelSet::load(&[], &platform, &dir, &cfg, &pool, &rec)
            .unwrap_err()
            .to_string();
        assert!(e.contains("at least one model"), "{e}");
        let specs = vec!["tinycnn".to_string(), "tinycnn".to_string()];
        let e = ModelSet::load(&specs, &platform, &dir, &cfg, &pool, &rec)
            .unwrap_err()
            .to_string();
        assert!(e.contains("duplicate model 'tinycnn'"), "{e}");
    }

    #[test]
    fn load_orders_slots_by_spec_and_sweeps_each() {
        let platform = Platform::diana();
        let pool = ThreadPool::new(2);
        let dir = std::env::temp_dir().join("odimo_multi_load");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SweepCfg { seed: 7, calib: 4, blend_steps: 2 };
        let rec = Recorder::disabled();
        let specs = vec!["tinycnn".to_string(), "resnet20".to_string()];
        let set = ModelSet::load(&specs, &platform, &dir, &cfg, &pool, &rec).unwrap();
        assert_eq!(set.names(), vec!["tinycnn".to_string(), "resnet20".to_string()]);
        assert_eq!(set.len(), 2);
        for slot in set.slots() {
            assert!(!slot.frontier.is_empty());
            assert!(!slot.param_names.is_empty());
        }
        // both frontier caches landed on disk under their own keys
        assert!(sweep::frontier_path(&dir, "tinycnn", "diana").exists());
        assert!(sweep::frontier_path(&dir, "resnet20", "diana").exists());
        // the borrowed views line up with the slots
        let params = set.param_sets();
        let models = set.cluster_models(&params);
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].graph.name, "tinycnn");
        assert_eq!(models[1].frontier.len(), set.slots()[1].frontier.len());
        // a mixed synthetic trace interleaves both models sorted by
        // arrival, and the single-model case is byte-identical to
        // Trace::synth
        let opts = ServeOpts::default();
        let mixed = synth_mixed(&opts, 8, 7, &set);
        assert_eq!(mixed.len(), 16);
        for w in mixed.records.windows(2) {
            assert!(w[0].arrival_cycle <= w[1].arrival_cycle);
        }
        assert!(mixed.records.iter().any(|r| r.model == "tinycnn"));
        assert!(mixed.records.iter().any(|r| r.model == "resnet20"));
        let solo_specs = vec!["tinycnn".to_string()];
        let solo =
            ModelSet::load(&solo_specs, &platform, &dir, &cfg, &pool, &rec).unwrap();
        let a = synth_mixed(&opts, 8, 7, &solo);
        let b = Trace::synth(&opts, 8, 7, &solo.slots()[0].frontier, "tinycnn");
        assert_eq!(a, b);
    }
}
