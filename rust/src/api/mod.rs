//! `odimo::api` — the one typed entry point for the whole deploy flow:
//! **map → simulate → deploy → infer → sweep → serve**.
//!
//! Everything the CLI verbs, the examples and the benches used to
//! re-thread by hand (`Graph`, `&Platform`, mapping dispatch, thread
//! pool, seed, directories, smoke sizing) is validated once by
//! [`SessionBuilder::build`] and then owned by a [`Session`]:
//!
//! ```text
//!   SessionBuilder ── validates once ──> Session
//!     model ──────> Graph (loaded)        ├─ mapping(MappingSpec)   Mapping
//!     platform ───> Platform (resolved)   ├─ simulate(&Mapping)     RunReport
//!     threads ────> ThreadPool (spawned)  ├─ deploy(&Mapping)       DeployReport
//!     seed, dirs,                         ├─ infer(&Mapping, x, n)  logits
//!     smoke, knobs                        ├─ sweep()                SweepResult
//!                                         ├─ serve(&ServeOpts)      ServeReport
//!                                         ├─ serve_cluster(&ClusterOpts, Option<&Trace>)
//!                                         │                         ClusterReport
//!                                         └─ serve_multi(&[spec], &ClusterOpts, Option<&Trace>)
//!                                                                   ClusterReport
//!               owned, reused state:  plan cache (LRU, shared by
//!               infer + serve) and the lazily built/cached frontier
//! ```
//!
//! The crate's internal engines (`hw::soc::simulate`, the scheduler,
//! the closed-loop serve driver) stay where they are; this module is
//! the only supported way to *drive* them. Scale-out follows from the
//! ownership story: replicas are "N sessions", and anything async
//! hangs off session-owned state instead of globals.
//!
//! See [`SessionBuilder`] for a doc-tested end-to-end example.
//!
//! Fault-tolerant serving is part of the same surface: put a
//! [`FaultPlan`] and an [`AdmissionCfg`] into [`ServeOpts`] and
//! [`Session::serve`] runs the degraded-mode driver
//! (docs/ARCHITECTURE.md §Faults) — no separate entry point.

#![deny(missing_docs)]
#![warn(clippy::unwrap_used, clippy::expect_used)]

mod session;

pub use crate::coordinator::baselines::CostObjective;
pub use crate::hw::faults::{FaultEvent, FaultPlan};
pub use crate::quant::{ConvAlgo, Isa, KernelBackend};
pub use crate::serve::{
    AdmissionCfg, ClusterOpts, ClusterReport, ModelRow, ModelSet, ModelSlot, ModelTenantRow,
    ServeError, ServeOpts, ServeReport, TenantRow, Trace, TraceError, TraceRecord,
};
pub use session::{MappingSpec, Session, SessionBuilder, SweepResult};
