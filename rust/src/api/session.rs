//! [`SessionBuilder`] / [`Session`] — the typed workflow facade.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};
use once_cell::sync::OnceCell;

use crate::config::RunConfig;
use crate::coordinator::baselines::{self, CostObjective, BASELINE_NAMES};
use crate::coordinator::scheduler::{self, DeployReport};
use crate::coordinator::Mapping;
use crate::hw::soc::{simulate, RunReport, SocConfig};
use crate::hw::Platform;
use crate::model::Graph;
use crate::obs::{export, EventKind, ObsLevel, Recorder};
use crate::quant::{synth_params_on, KernelBackend, ParamSet, QuantNet, QuantPlan};
use crate::serve::batcher::PlanCache;
use crate::serve::{
    self, cluster, metrics, multi, sweep, ClusterOpts, ClusterReport, FrontierPoint, ModelSet,
    ServeOpts, ServeReport, SweepCfg, Trace,
};
use crate::util::json;
use crate::util::pool::ThreadPool;

/// How a [`Session`] produces a [`Mapping`] — the typed replacement for
/// the stringly `--baseline <name> | --mapping <file>` dispatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MappingSpec {
    /// A named baseline (see `coordinator::baselines::BASELINE_NAMES`:
    /// `all_8bit`, `all_ternary`, `io8_backbone_ternary`, `even_split`,
    /// `min_cost_lat`, `min_cost_en`).
    Baseline(String),
    /// A mapping JSON file previously written by the pipeline.
    File(PathBuf),
    /// The static min-cost optimum under the given objective
    /// (water-filling for latency, Pareto DP for energy).
    MinCost(CostObjective),
}

/// The lazily built, in-memory + on-disk cached sweep frontier.
#[derive(Debug)]
pub struct SweepResult {
    /// Pareto frontier points, latency-ascending.
    pub points: Vec<FrontierPoint>,
    /// Whether the points were loaded from a valid on-disk cache
    /// (same sweep knobs *and* same platform spec hash).
    pub cache_hit: bool,
}

/// Builder for a [`Session`]: collects (model, platform, threads, seed,
/// directories, smoke) and validates everything once in
/// [`SessionBuilder::build`].
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use odimo::api::{MappingSpec, SessionBuilder};
///
/// let results = std::env::temp_dir().join("odimo_api_doc");
/// let session = SessionBuilder::new("tinycnn")
///     .platform("diana") // built-in name or a platform .toml path
///     .threads(2)
///     .seed(7)
///     .results_dir(&results)
///     .build()?;
/// let mapping = session.mapping(&MappingSpec::Baseline("min_cost_lat".into()))?;
/// let report = session.simulate(&mapping)?;
/// assert!(report.total_cycles > 0);
/// # Ok(()) }
/// ```
#[derive(Clone, Debug)]
pub struct SessionBuilder {
    model: String,
    platform: PlatformArg,
    threads: Option<usize>,
    seed: u64,
    smoke: bool,
    non_ideal_l1: bool,
    artifacts_dir: PathBuf,
    results_dir: PathBuf,
    plan_cache_cap: usize,
    sweep_calib: usize,
    sweep_blend_steps: usize,
    kernels: KernelBackend,
    obs_level: ObsLevel,
}

#[derive(Clone, Debug)]
enum PlatformArg {
    /// Built-in name or TOML path, resolved at build time.
    Named(String),
    /// An already-resolved platform (programmatic use, tests).
    Spec(Box<Platform>),
}

impl SessionBuilder {
    /// Start a builder for `model` (see `model::ALL_MODELS`) with the
    /// default platform (`diana`), seed 1234, machine-sized thread
    /// pool, and `artifacts` / `results` directories.
    pub fn new(model: impl Into<String>) -> Self {
        let sweep = SweepCfg::default();
        SessionBuilder {
            model: model.into(),
            platform: PlatformArg::Named("diana".into()),
            threads: None,
            seed: 1234,
            smoke: false,
            non_ideal_l1: false,
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            plan_cache_cap: 8,
            sweep_calib: sweep.calib,
            sweep_blend_steps: sweep.blend_steps,
            kernels: KernelBackend::Auto,
            obs_level: ObsLevel::Off,
        }
    }

    /// Builder preset from a [`RunConfig`] (CLI `--config` path): model,
    /// platform, directories, data seed and the L1 ablation switch.
    pub fn from_run_config(cfg: &RunConfig) -> Self {
        let mut b = SessionBuilder::new(cfg.model.clone());
        b.platform = PlatformArg::Spec(Box::new(cfg.platform.clone()));
        b.artifacts_dir = cfg.artifacts_dir.clone();
        b.results_dir = cfg.results_dir.clone();
        b.seed = cfg.data_seed;
        b.non_ideal_l1 = cfg.non_ideal_l1;
        b
    }

    /// Replace the model this builder targets (CLI override layering).
    pub fn model(mut self, model: impl Into<String>) -> Self {
        self.model = model.into();
        self
    }

    /// Deployment platform: a built-in name (`diana`, `diana_ne16`,
    /// `gap9`, `mpsoc4`) or a platform `.toml` path.
    pub fn platform(mut self, name_or_path: impl Into<String>) -> Self {
        self.platform = PlatformArg::Named(name_or_path.into());
        self
    }

    /// Deployment platform from an already-constructed spec.
    pub fn platform_spec(mut self, platform: Platform) -> Self {
        self.platform = PlatformArg::Spec(Box::new(platform));
        self
    }

    /// Worker threads for engine runs (sweep scoring, `infer`, serve
    /// batches). Must be >= 1; default: machine parallelism, capped.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Global seed: synthetic parameters, calibration batches, and the
    /// serve request stream all derive from it.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Smoke mode: tiny serve request streams (CI-sized defaults).
    pub fn smoke(mut self, on: bool) -> Self {
        self.smoke = on;
        self
    }

    /// Enable L1 tiling penalties in the SoC simulator (ablation knob;
    /// `simulate`/`deploy` only — `sweep`/`serve` refuse to run on a
    /// non-ideal-L1 session because the frontier is always scored
    /// ideal-L1, mirroring the CLI's `--non-ideal-l1` rejection).
    pub fn non_ideal_l1(mut self, on: bool) -> Self {
        self.non_ideal_l1 = on;
        self
    }

    /// Directory holding AOT artifacts (reserved for pipeline verbs).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Directory for the frontier cache and serve reports.
    pub fn results_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.results_dir = dir.into();
        self
    }

    /// Capacity of the session-owned LRU plan cache (default 8).
    pub fn plan_cache_cap(mut self, cap: usize) -> Self {
        self.plan_cache_cap = cap;
        self
    }

    /// Calibration images scored per sweep candidate (default 16).
    pub fn sweep_calib(mut self, calib: usize) -> Self {
        self.sweep_calib = calib;
        self
    }

    /// Sweep blend grid density (default 4).
    pub fn sweep_blend_steps(mut self, steps: usize) -> Self {
        self.sweep_blend_steps = steps;
        self
    }

    /// Kernel backend for every engine run this session compiles
    /// (`infer` and `serve`; the CLI `--kernels` flag lands here).
    /// Default [`KernelBackend::Auto`]: runtime CPU-feature dispatch,
    /// overridable via the `ODIMO_KERNELS` environment variable. All
    /// backends are bit-identical, so this is purely a speed knob.
    pub fn kernels(mut self, backend: KernelBackend) -> Self {
        self.kernels = backend;
        self
    }

    /// Observability level for this session's [`Recorder`] (default
    /// [`ObsLevel::Off`]: the disabled recorder is a no-op on every
    /// hot path). `Basic` records the deterministic virtual-cycle
    /// span/event stream; `Full` adds wall-clock engine and kernel
    /// spans (and routes serve batches through the single-threaded
    /// traced engine walk — bit-identical logits, different speed).
    pub fn observer(mut self, level: ObsLevel) -> Self {
        self.obs_level = level;
        self
    }

    /// Validate everything once and construct the [`Session`]: the
    /// model must resolve (a built-in name, or a path to an imported
    /// `odimo_graph` JSON file), the platform must resolve (built-in
    /// name or readable TOML), and `threads`, if set, must be >= 1.
    pub fn build(self) -> Result<Session> {
        let graph = multi::resolve_graph(&self.model)?;
        let platform = match self.platform {
            PlatformArg::Named(s) => Platform::resolve(&s)?,
            PlatformArg::Spec(p) => *p,
        };
        if self.threads == Some(0) {
            return Err(anyhow!("threads must be >= 1 (got 0)"));
        }
        let sweep_cfg = SweepCfg {
            seed: self.seed,
            calib: self.sweep_calib,
            blend_steps: self.sweep_blend_steps,
        };
        Ok(Session {
            graph,
            platform,
            threads: self.threads,
            pool: OnceCell::new(),
            seed: self.seed,
            smoke: self.smoke,
            soc: SocConfig { non_ideal_l1: self.non_ideal_l1 },
            artifacts_dir: self.artifacts_dir,
            results_dir: self.results_dir,
            sweep_cfg,
            frontier: None,
            plans: PlanCache::new(self.plan_cache_cap),
            params: None,
            kernels: self.kernels,
            rec: Recorder::new(self.obs_level),
        })
    }
}

/// One validated (model, platform) workflow context — the only public
/// entry point for map → simulate → deploy → infer → sweep → serve.
///
/// The session owns the loaded [`Graph`], the resolved [`Platform`],
/// the worker [`ThreadPool`], the LRU plan cache, and the lazily
/// built/cached sweep frontier; every method reuses that state, so
/// repeated calls never re-validate, re-resolve, re-spawn or
/// re-compile what the session already holds. Replicas are "N
/// sessions": each owns its pool and caches outright, nothing is
/// global.
pub struct Session {
    graph: Graph,
    platform: Platform,
    /// Validated worker-thread request (`None` = machine default); the
    /// pool itself spawns lazily so report-reading or simulator-only
    /// sessions never start worker threads.
    threads: Option<usize>,
    pool: OnceCell<ThreadPool>,
    seed: u64,
    smoke: bool,
    soc: SocConfig,
    artifacts_dir: PathBuf,
    results_dir: PathBuf,
    sweep_cfg: SweepCfg,
    frontier: Option<SweepResult>,
    plans: PlanCache,
    /// Synthetic parameter snapshot (names, values), built on first use
    /// by `infer`/`serve` from (graph, platform, seed) — the same
    /// derivation the sweep scorer uses, so served logits match swept
    /// logits.
    params: Option<(Vec<String>, Vec<Vec<f32>>)>,
    /// Kernel backend for every plan this session compiles.
    kernels: KernelBackend,
    /// The session's span/event recorder (see [`SessionBuilder::observer`]).
    rec: Recorder,
}

impl Session {
    /// The loaded model graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The resolved deployment platform.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// The session's worker pool (spawned on first use).
    pub fn pool(&self) -> &ThreadPool {
        init_pool(&self.pool, self.threads)
    }

    /// The session seed (parameters, calibration, request streams).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The kernel backend this session compiles plans with.
    pub fn kernels(&self) -> KernelBackend {
        self.kernels
    }

    /// Whether the session runs smoke-sized defaults.
    pub fn smoke(&self) -> bool {
        self.smoke
    }

    /// The artifacts directory the session was built with.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// The results directory (frontier cache, serve reports).
    pub fn results_dir(&self) -> &Path {
        &self.results_dir
    }

    /// The session-owned plan cache (hit/miss/compile-time counters).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    /// The session's span/event recorder. Disabled unless the session
    /// was built with [`SessionBuilder::observer`].
    pub fn recorder(&self) -> &Recorder {
        &self.rec
    }

    /// Export the recorder's current event stream as a Chrome
    /// trace-event / Perfetto JSON file (written atomically). Call
    /// after `serve`/`serve_cluster`; each of those resets the stream
    /// at entry, so the file holds exactly the last run.
    pub fn export_trace(&self, path: &Path) -> Result<()> {
        let points: &[FrontierPoint] =
            self.frontier.as_ref().map(|f| f.points.as_slice()).unwrap_or(&[]);
        let ctx = export::TraceCtx {
            graph: &self.graph,
            platform: &self.platform,
            points,
            cfg: self.soc,
        };
        export::write_trace_events(path, &self.rec.snapshot(), &ctx)
    }

    /// On-disk path of this session's frontier cache file.
    pub fn frontier_path(&self) -> PathBuf {
        sweep::frontier_path(&self.results_dir, &self.graph.name, &self.platform.name)
    }

    /// On-disk path of this session's serve report.
    pub fn report_path(&self) -> PathBuf {
        serve::report_path(&self.results_dir, &self.graph.name, &self.platform.name)
    }

    /// Produce (and validate) a mapping from a typed [`MappingSpec`].
    pub fn mapping(&self, spec: &MappingSpec) -> Result<Mapping> {
        let mapping = match spec {
            MappingSpec::Baseline(name) => baselines::by_name(&self.graph, &self.platform, name)
                .ok_or_else(|| {
                    anyhow!("unknown baseline '{name}' (choose from {BASELINE_NAMES:?})")
                })?,
            MappingSpec::File(path) => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("reading mapping {}: {e}", path.display()))?;
                Mapping::from_json(&json::parse(&text)?)?
            }
            MappingSpec::MinCost(objective) => {
                baselines::min_cost(&self.graph, &self.platform, *objective)
            }
        };
        mapping.validate(&self.graph, self.platform.n_acc())?;
        Ok(mapping)
    }

    /// Cost `mapping` on the SoC simulator (cycles, ms, uJ, per-unit
    /// utilization, Fig.-6 timeline) under the session's simulator
    /// config.
    pub fn simulate(&self, mapping: &Mapping) -> Result<RunReport> {
        mapping.validate(&self.graph, self.platform.n_acc())?;
        Ok(simulate(
            &self.graph,
            &mapping.channel_split(self.platform.n_acc()),
            &self.platform,
            self.soc,
        ))
    }

    /// Deploy `mapping` through the scheduler: simulator cost plus
    /// fragmentation overhead and per-layer fragment counts.
    pub fn deploy(&self, mapping: &Mapping) -> Result<DeployReport> {
        mapping.validate(&self.graph, self.platform.n_acc())?;
        Ok(scheduler::deploy(&self.graph, mapping, &self.platform, self.soc))
    }

    /// Run one quantized-engine batch under `mapping`: `x` is NCHW in
    /// [0, 1], `batch` images; returns (batch, classes) logits. Plans
    /// compile once per mapping into the session-owned LRU cache and
    /// are replayed on every later call (the serve path shares the same
    /// cache). Parameters are the session's seeded synthetic snapshot.
    pub fn infer(&mut self, mapping: &Mapping, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        mapping.validate(&self.graph, self.platform.n_acc())?;
        self.ensure_params();
        let (names, values) = self
            .params
            .as_ref()
            .ok_or_else(|| anyhow!("internal: parameter snapshot missing after ensure_params"))?;
        let key = QuantPlan::cache_key(
            &self.graph.name,
            self.graph.spec_hash(),
            &self.platform.name,
            mapping,
            self.kernels,
        );
        let graph = &self.graph;
        let platform = &self.platform;
        let backend = self.kernels;
        let pool = init_pool(&self.pool, self.threads);
        // the ParamSet (a name-indexed view) is only needed when the
        // plan actually compiles, so build it inside the miss closure —
        // the steady-state hit path pays one hash + mapping compare
        let net = self.plans.get_or_compile(key, mapping, || {
            let params = ParamSet::new(names.iter().map(|s| s.as_str()), values);
            QuantNet::compile_params_backend(&params, graph, mapping, platform, backend)
        })?;
        net.forward_pool(x, batch, pool)
    }

    /// Build — or load from the invalidation-aware disk cache — the
    /// sweep frontier for this (model, platform). The result is also
    /// cached in memory, so later calls (and `serve`) are free. The
    /// disk cache is keyed by sweep knobs *and* the platform's
    /// [`Platform::spec_hash`], so an edited platform TOML re-sweeps
    /// instead of silently reusing stale points.
    pub fn sweep(&mut self) -> Result<&SweepResult> {
        // mirror the CLI's rejection of --non-ideal-l1 on sweep/serve:
        // the frontier is always scored under the ideal-L1 simulator
        // config, so serving from it with a different simulate() config
        // would make SLA decisions disagree with the session's own
        // simulator numbers
        if self.soc.non_ideal_l1 {
            return Err(anyhow!(
                "sweep/serve score the ideal-L1 simulator config; build the \
                 session without non_ideal_l1 to use the frontier"
            ));
        }
        if self.frontier.is_none() {
            let (points, cache_hit) = sweep::load_or_sweep(
                &self.results_dir,
                &self.graph,
                &self.platform,
                &self.sweep_cfg,
                init_pool(&self.pool, self.threads),
                &self.rec,
            )?;
            if points.is_empty() {
                return Err(anyhow!(
                    "empty frontier for {} on {}",
                    self.graph.name,
                    self.platform.name
                ));
            }
            self.frontier = Some(SweepResult { points, cache_hit });
        }
        self.frontier
            .as_ref()
            .ok_or_else(|| anyhow!("internal: frontier missing after sweep"))
    }

    /// Run the closed-loop SLA-aware serving driver over the session's
    /// frontier and plan cache, persist the report under the results
    /// directory, and return it. Deterministic in (model, platform
    /// spec, seed, opts) for everything except wall-clock throughput —
    /// including faults: `opts.fault_plan` scripts unit failures on the
    /// virtual timeline and `opts.admission` bounds overload, and the
    /// returned report accounts every request as served, shed, or
    /// failed (`ServeReport::accounted`).
    pub fn serve(&mut self, opts: &ServeOpts) -> Result<ServeReport> {
        let n_requests = opts
            .n_requests
            .unwrap_or(if self.smoke { 24 } else { 96 });
        // one event stream per run: back-to-back serves each export
        // exactly their own trace
        self.rec.reset();
        self.sweep()?;
        self.ensure_params();
        let (names, values) = self
            .params
            .as_ref()
            .ok_or_else(|| anyhow!("internal: parameter snapshot missing after ensure_params"))?;
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), values);
        let frontier = &self
            .frontier
            .as_ref()
            .ok_or_else(|| anyhow!("internal: frontier missing after sweep"))?
            .points;
        let report = serve::run_serve(
            &self.graph,
            &self.platform,
            &params,
            frontier,
            init_pool(&self.pool, self.threads),
            &mut self.plans,
            opts,
            n_requests,
            self.seed,
            self.kernels,
            &self.rec,
        )?;
        let path = serve::report_path(&self.results_dir, &self.graph.name, &self.platform.name);
        metrics::save_report(&path, &report)?;
        self.rec.note(
            log::Level::Info,
            EventKind::ReportWritten { kind: "serve report", path: path.display().to_string() },
        );
        Ok(report)
    }

    /// Synthesize the session's canonical request trace for `opts`:
    /// the exact stream `serve` would generate internally (arrivals,
    /// SLAs, tenants, per-record seeds), as a saveable/replayable
    /// [`Trace`]. Sweeps the frontier first (arrival SLA budgets are
    /// drawn around the frontier's own latency range).
    pub fn synth_trace(&mut self, opts: &ServeOpts) -> Result<Trace> {
        let n_requests = opts
            .n_requests
            .unwrap_or(if self.smoke { 24 } else { 96 });
        self.sweep()?;
        let frontier = &self
            .frontier
            .as_ref()
            .ok_or_else(|| anyhow!("internal: frontier missing after sweep"))?
            .points;
        Ok(Trace::synth(opts, n_requests, self.seed, frontier, &self.graph.name))
    }

    /// Synthesize the canonical mixed request trace for a multi-model
    /// serving set: `opts.n_requests` requests *per model* (slot `i`
    /// draws from `seed + i`), merged by arrival — exactly the stream
    /// [`Session::serve_multi`] generates internally when given no
    /// trace. Resolves and sweeps every spec through the disk cache
    /// first (arrival SLA budgets derive from each model's own
    /// frontier).
    pub fn synth_trace_multi(&self, specs: &[String], opts: &ServeOpts) -> Result<Trace> {
        let n = opts.n_requests.unwrap_or(if self.smoke { 24 } else { 96 });
        let pool = init_pool(&self.pool, self.threads);
        let set = ModelSet::load(
            specs,
            &self.platform,
            &self.results_dir,
            &self.sweep_cfg,
            pool,
            &self.rec,
        )?;
        Ok(multi::synth_mixed(opts, n, self.seed, &set))
    }

    /// Run the replicated cluster driver (`opts.replicas` virtual
    /// replicas, least-loaded routing, bounded work stealing,
    /// continuous batching, compile-ahead gating) over `trace` — or
    /// over the synthesized canonical trace when `trace` is `None` —
    /// persist the [`ClusterReport`] under the results directory, and
    /// return it. Fully deterministic in (trace, platform spec, opts):
    /// the digest is invariant across worker thread counts.
    pub fn serve_cluster(
        &mut self,
        opts: &ClusterOpts,
        trace: Option<&Trace>,
    ) -> Result<ClusterReport> {
        // one event stream per run: back-to-back runs each export
        // exactly their own trace
        self.rec.reset();
        let owned;
        let trace = match trace {
            Some(t) => t,
            None => {
                owned = self.synth_trace(&opts.serve)?;
                &owned
            }
        };
        self.sweep()?;
        self.ensure_params();
        let (names, values) = self
            .params
            .as_ref()
            .ok_or_else(|| anyhow!("internal: parameter snapshot missing after ensure_params"))?;
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), values);
        let frontier = &self
            .frontier
            .as_ref()
            .ok_or_else(|| anyhow!("internal: frontier missing after sweep"))?
            .points;
        let report = cluster::run_cluster(
            &self.graph,
            &self.platform,
            &params,
            frontier,
            init_pool(&self.pool, self.threads),
            trace,
            opts,
            self.kernels,
            &self.rec,
        )?;
        let path = cluster::cluster_report_path(
            &self.results_dir,
            &self.graph.name,
            &self.platform.name,
        );
        cluster::save_cluster_report(&path, &report)?;
        self.rec.note(
            log::Level::Info,
            EventKind::ReportWritten { kind: "cluster report", path: path.display().to_string() },
        );
        Ok(report)
    }

    /// Serve a *set* of models on one cluster: resolve every spec (a
    /// built-in name or an imported-graph JSON path), sweep each
    /// model's frontier through the disk cache, route `trace` records
    /// to models by name, and run the multi-model cluster driver —
    /// batches never mix models, flush order is deficit-round-robin
    /// fair across models, and the report carries per-(model, tenant)
    /// accounting rows. With one model and the same trace this is
    /// digest-identical to [`Session::serve_cluster`]. When `trace` is
    /// `None`, a mixed stream is synthesized: `opts.serve.n_requests`
    /// requests *per model* (slot `i` draws from `seed + i`), merged by
    /// arrival. The session's own model plays no role here: the serving
    /// set is exactly `specs`. The report persists under the results
    /// directory keyed by the joined model names.
    pub fn serve_multi(
        &mut self,
        specs: &[String],
        opts: &ClusterOpts,
        trace: Option<&Trace>,
    ) -> Result<ClusterReport> {
        // mirror sweep()'s rejection: frontiers are scored ideal-L1
        if self.soc.non_ideal_l1 {
            return Err(anyhow!(
                "sweep/serve score the ideal-L1 simulator config; build the \
                 session without non_ideal_l1 to use the frontier"
            ));
        }
        // one event stream per run, as in serve/serve_cluster
        self.rec.reset();
        let pool = init_pool(&self.pool, self.threads);
        let set = ModelSet::load(
            specs,
            &self.platform,
            &self.results_dir,
            &self.sweep_cfg,
            pool,
            &self.rec,
        )?;
        let owned;
        let trace = match trace {
            Some(t) => t,
            None => {
                let n = opts
                    .serve
                    .n_requests
                    .unwrap_or(if self.smoke { 24 } else { 96 });
                owned = multi::synth_mixed(&opts.serve, n, self.seed, &set);
                &owned
            }
        };
        let params = set.param_sets();
        let models = set.cluster_models(&params);
        let report = cluster::run_cluster_multi(
            &models,
            &self.platform,
            pool,
            trace,
            opts,
            self.kernels,
            &self.rec,
        )?;
        let joined = set.names().join("+");
        let path =
            cluster::cluster_report_path(&self.results_dir, &joined, &self.platform.name);
        cluster::save_cluster_report(&path, &report)?;
        self.rec.note(
            log::Level::Info,
            EventKind::ReportWritten { kind: "cluster report", path: path.display().to_string() },
        );
        Ok(report)
    }

    /// Load the dashboard report of the last `serve` run for this
    /// (model, platform) from the results directory.
    pub fn serve_report(&self) -> Result<ServeReport> {
        let path = serve::report_path(&self.results_dir, &self.graph.name, &self.platform.name);
        metrics::load_report(&path)
            .map_err(|e| anyhow!("{e:#}\nrun `odimo serve` first to produce the report"))
    }

    fn ensure_params(&mut self) {
        if self.params.is_none() {
            let (names, values) = synth_params_on(&self.graph, &self.platform, self.seed);
            self.params = Some((names, values));
        }
    }
}

/// Spawn-on-first-use accessor for the session pool. A free function
/// over the cell (not a `&self` method) so callers holding disjoint
/// `&mut` borrows of other session fields can still reach the pool.
fn init_pool(cell: &OnceCell<ThreadPool>, threads: Option<usize>) -> &ThreadPool {
    cell.get_or_init(|| match threads {
        Some(n) => ThreadPool::new(n),
        None => ThreadPool::with_default_size(),
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::prng::Pcg32;

    fn session(model: &str, platform: &str, dir: &str) -> Session {
        let results = std::env::temp_dir().join(dir);
        let _ = std::fs::remove_dir_all(&results);
        SessionBuilder::new(model)
            .platform(platform)
            .threads(2)
            .seed(7)
            .results_dir(results)
            .sweep_calib(4)
            .sweep_blend_steps(2)
            .build()
            .unwrap()
    }

    // ---- golden parity: the facade must be bit-identical to the ----
    // ---- pre-refactor free-function paths it wraps             ----

    #[test]
    fn simulate_parity_with_direct_path() {
        for plat in ["diana", "mpsoc4"] {
            let s = session("tinycnn", plat, "odimo_api_sim_parity");
            for name in ["all_8bit", "even_split", "min_cost_lat", "min_cost_en"] {
                let m = s.mapping(&MappingSpec::Baseline(name.into())).unwrap();
                let got = s.simulate(&m).unwrap();
                let want = simulate(
                    s.graph(),
                    &m.channel_split(s.platform().n_acc()),
                    s.platform(),
                    SocConfig::default(),
                );
                assert_eq!(got.total_cycles, want.total_cycles, "{plat}/{name}");
                assert_eq!(got.energy_uj, want.energy_uj, "{plat}/{name}");
                assert_eq!(got.util, want.util, "{plat}/{name}");
                assert_eq!(got.channel_frac, want.channel_frac, "{plat}/{name}");
            }
        }
    }

    #[test]
    fn deploy_parity_with_direct_path() {
        for plat in ["diana", "mpsoc4"] {
            let s = session("tinycnn", plat, "odimo_api_dep_parity");
            for name in ["even_split", "min_cost_lat"] {
                let m = s.mapping(&MappingSpec::Baseline(name.into())).unwrap();
                let got = s.deploy(&m).unwrap();
                let want =
                    scheduler::deploy(s.graph(), &m, s.platform(), SocConfig::default());
                assert_eq!(got.run.total_cycles, want.run.total_cycles, "{plat}/{name}");
                assert_eq!(got.run.energy_uj, want.run.energy_uj, "{plat}/{name}");
                assert_eq!(
                    got.fragment_overhead_cycles, want.fragment_overhead_cycles,
                    "{plat}/{name}"
                );
                assert_eq!(got.fragments, want.fragments, "{plat}/{name}");
            }
        }
    }

    #[test]
    fn sweep_parity_with_direct_path() {
        for plat in ["diana", "mpsoc4"] {
            let mut s = session("tinycnn", plat, &format!("odimo_api_sweep_parity_{plat}"));
            let off = Recorder::disabled();
            let want =
                sweep::sweep_frontier(s.graph(), s.platform(), &s.sweep_cfg, s.pool(), &off)
                    .unwrap();
            let got = s.sweep().unwrap();
            assert!(!got.cache_hit, "first facade sweep computes fresh");
            assert_eq!(got.points.len(), want.len(), "{plat}");
            for (a, b) in got.points.iter().zip(&want) {
                assert_eq!(a.label, b.label, "{plat}");
                assert_eq!(a.cycles, b.cycles, "{plat}");
                assert_eq!(a.energy_uj, b.energy_uj, "{plat}");
                assert_eq!(a.acc_proxy, b.acc_proxy, "{plat}");
                assert_eq!(a.mapping, b.mapping, "{plat}");
            }
        }
    }

    #[test]
    fn infer_parity_with_direct_engine() {
        let mut s = session("tinycnn", "diana", "odimo_api_infer_parity");
        let m = s.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap();
        let (c, h, w) = s.graph().input_shape;
        let mut rng = Pcg32::new(5, 77);
        let x: Vec<f32> = (0..2 * c * h * w).map(|_| rng.next_f32()).collect();
        let got = s.infer(&m, &x, 2).unwrap();
        // the direct path, with the session's own parameter derivation
        let (names, values) = synth_params_on(s.graph(), s.platform(), s.seed());
        let params = ParamSet::new(names.iter().map(|n| n.as_str()), &values);
        let net = QuantNet::compile_params(&params, s.graph(), &m, s.platform()).unwrap();
        let want = net.forward_pool(&x, 2, s.pool()).unwrap();
        assert_eq!(got, want, "facade infer must be bit-identical");
        // second call is a plan-cache hit
        assert_eq!(s.plan_cache().misses, 1);
        let again = s.infer(&m, &x, 2).unwrap();
        assert_eq!(again, want);
        assert_eq!(s.plan_cache().hits, 1);
    }

    #[test]
    fn non_ideal_l1_flows_into_simulate() {
        let results = std::env::temp_dir().join("odimo_api_l1");
        let s = SessionBuilder::new("resnet20")
            .platform("diana")
            .threads(1)
            .results_dir(&results)
            .non_ideal_l1(true)
            .build()
            .unwrap();
        let m = s.mapping(&MappingSpec::Baseline("even_split".into())).unwrap();
        let got = s.simulate(&m).unwrap();
        let want = simulate(
            s.graph(),
            &m.channel_split(2),
            s.platform(),
            SocConfig { non_ideal_l1: true },
        );
        assert_eq!(got.total_cycles, want.total_cycles);
    }
}
