//! 3-accelerator deployment example — proves the platform registry's
//! generality end-to-end with no artifacts required.
//!
//! One `odimo::api::Session` is the whole setup: it loads the shipped
//! `config/diana_ne16.toml` platform (DIANA's int8 PE array + ternary
//! AIMC macro, plus an NE16-style 4-bit digital unit), builds min-cost
//! and even-split mappings of ResNet20 across all three units from
//! typed `MappingSpec`s, deploys them on the simulator, and prints a
//! report with per-unit utilization for every accelerator.
//!
//!     cargo run --release --example deploy_tri

use odimo::api::{MappingSpec, Session, SessionBuilder};

fn session() -> anyhow::Result<Session> {
    // prefer the TOML (exercising the config path); fall back to the
    // identical built-in when run from an unexpected cwd
    SessionBuilder::new("resnet20")
        .platform("config/diana_ne16.toml")
        .build()
        .or_else(|_| SessionBuilder::new("resnet20").platform("diana_ne16").build())
}

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let session = session()?;
    let platform = session.platform();
    println!(
        "platform {}: {} accelerators ({})",
        platform.name,
        platform.n_acc(),
        platform.acc_names().join(", ")
    );

    for name in ["even_split", "min_cost_lat", "min_cost_en", "all_8bit"] {
        let mapping = session.mapping(&MappingSpec::Baseline(name.into()))?;
        let rep = session.deploy(&mapping)?;
        let util = platform
            .accelerators
            .iter()
            .zip(&rep.run.util)
            .map(|(a, u)| format!("{} {:5.1}%", a.name, 100.0 * u))
            .collect::<Vec<_>>()
            .join(" | ");
        let ch = platform
            .accelerators
            .iter()
            .zip(&rep.run.channel_frac)
            .map(|(a, f)| format!("{} {:4.1}%", a.name, 100.0 * f))
            .collect::<Vec<_>>()
            .join(" | ");
        println!(
            "\n{name:>14}: {:.3} ms | {:.2} uJ | {} cycles",
            rep.run.latency_ms, rep.run.energy_uj, rep.run.total_cycles
        );
        println!("{:>14}  util: {util}", "");
        println!("{:>14}  ch:   {ch}", "");
    }

    // per-layer breakdown of the even split (first rows)
    let mapping = session.mapping(&MappingSpec::Baseline("even_split".into()))?;
    let rep = session.deploy(&mapping)?;
    println!("\nper-layer busy cycles, even_split (first 8 rows):");
    print!("{:<12}", "layer");
    for a in &platform.accelerators {
        print!(" {:>10}", a.name);
    }
    println!(" {:>10}", "span");
    for (layer, busy, span) in rep.run.timeline.per_layer().into_iter().take(8) {
        print!("{layer:<12}");
        for b in &busy {
            print!(" {b:>10}");
        }
        println!(" {span:>10}");
    }
    let u = rep.run.timeline.utilization();
    println!(
        "\nall-busy {:.1}% | idle {:.1}% | union {:.1}%",
        100.0 * u.all_busy_frac,
        100.0 * u.idle_frac,
        100.0 * u.union_frac
    );
    Ok(())
}
