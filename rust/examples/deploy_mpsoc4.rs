//! 4-unit heterogeneous MPSoC deployment example — the many-unit
//! stress case: an int8 NPU, two IMC macros with *distinct* D/A widths
//! (7-bit + 6-bit), and a GPU-style proportional unit.
//!
//! Loads `config/mpsoc4.toml` (falling back to the identical built-in),
//! builds the water-filling min-cost mapping of ResNet20 over all four
//! units (the exhaustive enumerator would need ~cout^3 compositions per
//! layer here — see `make bench-mincost` for the measured gap), deploys
//! it on the simulator with per-unit utilization, and proves the
//! per-width D/A engine bit-exact against the naive oracle.
//!
//!     cargo run --release --example deploy_mpsoc4

use odimo::coordinator::{baselines, scheduler::deploy};
use odimo::hw::soc::SocConfig;
use odimo::hw::Platform;
use odimo::quant::r#ref::RefNet;
use odimo::quant::{synth_params_on, ParamSet, QuantNet};
use odimo::util::prng::Pcg32;

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let platform = Platform::from_toml_file(std::path::Path::new("config/mpsoc4.toml"))
        .unwrap_or_else(|_| Platform::mpsoc4());
    let g = odimo::model::resnet20();
    println!(
        "platform {}: {} accelerators ({}), D/A widths {:?}",
        platform.name,
        platform.n_acc(),
        platform.acc_names().join(", "),
        platform.da_widths(),
    );

    for name in ["even_split", "min_cost_lat", "min_cost_en", "all_8bit"] {
        let mapping = baselines::by_name(&g, &platform, name).expect("baseline");
        mapping.validate(&g, platform.n_acc())?;
        let rep = deploy(&g, &mapping, &platform, SocConfig::default());
        let util = platform
            .accelerators
            .iter()
            .zip(&rep.run.util)
            .map(|(a, u)| format!("{} {:5.1}%", a.name, 100.0 * u))
            .collect::<Vec<_>>()
            .join(" | ");
        let ch = platform
            .accelerators
            .iter()
            .zip(&rep.run.channel_frac)
            .map(|(a, f)| format!("{} {:4.1}%", a.name, 100.0 * f))
            .collect::<Vec<_>>()
            .join(" | ");
        println!(
            "\n{name:>14}: {:.3} ms | {:.2} uJ | {} cycles",
            rep.run.latency_ms, rep.run.energy_uj, rep.run.total_cycles
        );
        println!("{:>14}  util: {util}", "");
        println!("{:>14}  ch:   {ch}", "");
    }

    // the acceptance gate: water-filling min-cost deployed through the
    // quantized engine, bit-exact vs the oracle despite two distinct
    // D/A widths coexisting per layer
    let tg = odimo::model::tinycnn();
    let (names, values) = synth_params_on(&tg, &platform, 13);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = baselines::min_cost(&tg, &platform, baselines::CostObjective::Latency);
    mapping.validate(&tg, platform.n_acc())?;
    let engine = QuantNet::compile_params(&params, &tg, &mapping, &platform)?;
    let oracle = RefNet::compile(&params, &tg, &mapping, &platform)?;
    let (c, h, w) = tg.input_shape;
    let mut rng = Pcg32::new(17, 77);
    let x: Vec<f32> = (0..2 * c * h * w).map(|_| rng.next_f32()).collect();
    let got = engine.forward(&x, 2)?;
    let want = oracle.forward(&x, 2)?;
    let diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "\nwater-filled min-cost through the quant engine vs oracle on {}: max |diff| = {diff:e}",
        tg.name
    );
    assert!(diff < 1e-4, "engine diverged from oracle");
    println!("bit-exact: OK");
    Ok(())
}
