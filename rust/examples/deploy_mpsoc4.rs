//! 4-unit heterogeneous MPSoC deployment example — the many-unit
//! stress case: an int8 NPU, two IMC macros with *distinct* D/A widths
//! (7-bit + 6-bit), and a GPU-style proportional unit.
//!
//! Loads `config/mpsoc4.toml` (falling back to the identical built-in)
//! into an `odimo::api::Session`, deploys the water-filling min-cost
//! mapping of ResNet20 over all four units (the exhaustive enumerator
//! would need ~cout^3 compositions per layer here — see `make
//! bench-mincost` for the measured gap) with per-unit utilization, and
//! proves the per-width D/A engine behind `Session::infer` bit-exact
//! against the naive oracle.
//!
//!     cargo run --release --example deploy_mpsoc4

use odimo::api::{CostObjective, MappingSpec, SessionBuilder};
use odimo::quant::r#ref::RefNet;
use odimo::quant::{synth_params_on, ParamSet};
use odimo::util::prng::Pcg32;

fn builder(model: &str) -> SessionBuilder {
    SessionBuilder::new(model).platform("config/mpsoc4.toml")
}

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let session = builder("resnet20")
        .build()
        .or_else(|_| SessionBuilder::new("resnet20").platform("mpsoc4").build())?;
    let platform = session.platform();
    println!(
        "platform {}: {} accelerators ({}), D/A widths {:?}",
        platform.name,
        platform.n_acc(),
        platform.acc_names().join(", "),
        platform.da_widths(),
    );

    for name in ["even_split", "min_cost_lat", "min_cost_en", "all_8bit"] {
        let mapping = session.mapping(&MappingSpec::Baseline(name.into()))?;
        let rep = session.deploy(&mapping)?;
        let util = platform
            .accelerators
            .iter()
            .zip(&rep.run.util)
            .map(|(a, u)| format!("{} {:5.1}%", a.name, 100.0 * u))
            .collect::<Vec<_>>()
            .join(" | ");
        let ch = platform
            .accelerators
            .iter()
            .zip(&rep.run.channel_frac)
            .map(|(a, f)| format!("{} {:4.1}%", a.name, 100.0 * f))
            .collect::<Vec<_>>()
            .join(" | ");
        println!(
            "\n{name:>14}: {:.3} ms | {:.2} uJ | {} cycles",
            rep.run.latency_ms, rep.run.energy_uj, rep.run.total_cycles
        );
        println!("{:>14}  util: {util}", "");
        println!("{:>14}  ch:   {ch}", "");
    }

    // the acceptance gate: water-filling min-cost deployed through the
    // session's quantized engine, bit-exact vs the oracle despite two
    // distinct D/A widths coexisting per layer
    let mut tsession = builder("tinycnn")
        .seed(13)
        .build()
        .or_else(|_| SessionBuilder::new("tinycnn").platform("mpsoc4").seed(13).build())?;
    let tg = tsession.graph().clone();
    let mapping = tsession.mapping(&MappingSpec::MinCost(CostObjective::Latency))?;
    let (c, h, w) = tg.input_shape;
    let mut rng = Pcg32::new(17, 77);
    let x: Vec<f32> = (0..2 * c * h * w).map(|_| rng.next_f32()).collect();
    let got = tsession.infer(&mapping, &x, 2)?;
    let (names, values) = synth_params_on(&tg, tsession.platform(), tsession.seed());
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let oracle = RefNet::compile(&params, &tg, &mapping, tsession.platform())?;
    let want = oracle.forward(&x, 2)?;
    let diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "\nwater-filled min-cost through the session engine vs oracle on {}: max |diff| = {diff:e}",
        tg.name
    );
    assert!(diff < 1e-4, "engine diverged from oracle");
    println!("bit-exact: OK");
    Ok(())
}
