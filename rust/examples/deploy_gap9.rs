//! GAP9-style deployment example — a platform with *no* IMC unit
//! (`da_bits` absent everywhere): an 8-core RISC-V cluster modeled
//! proportionally plus an NE16-style accelerator.
//!
//! Loads `config/gap9.toml` (falling back to the identical built-in),
//! builds the water-filling min-cost and even-split mappings of
//! ResNet20 across both units, deploys them on the simulator, and
//! verifies the quantized engine against the naive oracle — with no
//! D/A views materialized at all.
//!
//!     cargo run --release --example deploy_gap9

use odimo::coordinator::{baselines, scheduler::deploy};
use odimo::hw::soc::SocConfig;
use odimo::hw::Platform;
use odimo::quant::r#ref::RefNet;
use odimo::quant::{synth_mapping_n, synth_params_on, ParamSet, QuantNet};
use odimo::util::prng::Pcg32;

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let platform = Platform::from_toml_file(std::path::Path::new("config/gap9.toml"))
        .unwrap_or_else(|_| Platform::gap9());
    let g = odimo::model::resnet20();
    println!(
        "platform {}: {} accelerators ({}), D/A widths {:?}",
        platform.name,
        platform.n_acc(),
        platform.acc_names().join(", "),
        platform.da_widths(),
    );

    for name in ["even_split", "min_cost_lat", "min_cost_en"] {
        let mapping = baselines::by_name(&g, &platform, name).expect("baseline");
        mapping.validate(&g, platform.n_acc())?;
        let rep = deploy(&g, &mapping, &platform, SocConfig::default());
        let util = platform
            .accelerators
            .iter()
            .zip(&rep.run.util)
            .map(|(a, u)| format!("{} {:5.1}%", a.name, 100.0 * u))
            .collect::<Vec<_>>()
            .join(" | ");
        println!(
            "{name:>14}: {:.3} ms | {:.2} uJ | {} cycles | util {util}",
            rep.run.latency_ms, rep.run.energy_uj, rep.run.total_cycles
        );
    }

    // engine vs oracle on the tiny model (the oracle is a scalar
    // interpreter): bit-exactness without any D/A view
    let tg = odimo::model::tinycnn();
    let (names, values) = synth_params_on(&tg, &platform, 7);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = synth_mapping_n(&tg, platform.n_acc(), 11);
    let engine = QuantNet::compile_params(&params, &tg, &mapping, &platform)?;
    let oracle = RefNet::compile(&params, &tg, &mapping, &platform)?;
    let (c, h, w) = tg.input_shape;
    let mut rng = Pcg32::new(5, 77);
    let x: Vec<f32> = (0..2 * c * h * w).map(|_| rng.next_f32()).collect();
    let got = engine.forward(&x, 2)?;
    let want = oracle.forward(&x, 2)?;
    let diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("\nquant engine vs oracle on {}: max |diff| = {diff:e}", tg.name);
    assert!(diff < 1e-4, "engine diverged from oracle");
    Ok(())
}
