//! GAP9-style deployment example — a platform with *no* IMC unit
//! (`da_bits` absent everywhere): an 8-core RISC-V cluster modeled
//! proportionally plus an NE16-style accelerator.
//!
//! Loads `config/gap9.toml` (falling back to the identical built-in)
//! into an `odimo::api::Session`, deploys the water-filling min-cost
//! and even-split mappings of ResNet20 across both units, and verifies
//! `Session::infer` (the planned quantized engine, plan-cached inside
//! the session) against the naive oracle — with no D/A views
//! materialized at all.
//!
//!     cargo run --release --example deploy_gap9

use odimo::api::{MappingSpec, SessionBuilder};
use odimo::quant::r#ref::RefNet;
use odimo::quant::{synth_mapping_n, synth_params_on, ParamSet};
use odimo::util::prng::Pcg32;

fn builder(model: &str) -> SessionBuilder {
    SessionBuilder::new(model).platform("config/gap9.toml")
}

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let session = builder("resnet20")
        .build()
        .or_else(|_| SessionBuilder::new("resnet20").platform("gap9").build())?;
    let platform = session.platform();
    println!(
        "platform {}: {} accelerators ({}), D/A widths {:?}",
        platform.name,
        platform.n_acc(),
        platform.acc_names().join(", "),
        platform.da_widths(),
    );

    for name in ["even_split", "min_cost_lat", "min_cost_en"] {
        let mapping = session.mapping(&MappingSpec::Baseline(name.into()))?;
        let rep = session.deploy(&mapping)?;
        let util = platform
            .accelerators
            .iter()
            .zip(&rep.run.util)
            .map(|(a, u)| format!("{} {:5.1}%", a.name, 100.0 * u))
            .collect::<Vec<_>>()
            .join(" | ");
        println!(
            "{name:>14}: {:.3} ms | {:.2} uJ | {} cycles | util {util}",
            rep.run.latency_ms, rep.run.energy_uj, rep.run.total_cycles
        );
    }

    // engine vs oracle on the tiny model (the oracle is a scalar
    // interpreter): bit-exactness without any D/A view. The session is
    // seeded so its synthetic parameter snapshot is reproducible for
    // the oracle side.
    let mut tsession = builder("tinycnn")
        .seed(7)
        .build()
        .or_else(|_| SessionBuilder::new("tinycnn").platform("gap9").seed(7).build())?;
    let tg = tsession.graph().clone();
    let mapping = synth_mapping_n(&tg, tsession.platform().n_acc(), 11);
    let (c, h, w) = tg.input_shape;
    let mut rng = Pcg32::new(5, 77);
    let x: Vec<f32> = (0..2 * c * h * w).map(|_| rng.next_f32()).collect();
    let got = tsession.infer(&mapping, &x, 2)?;
    // the oracle, compiled over the same seeded parameter derivation
    let (names, values) = synth_params_on(&tg, tsession.platform(), tsession.seed());
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let oracle = RefNet::compile(&params, &tg, &mapping, tsession.platform())?;
    let want = oracle.forward(&x, 2)?;
    let diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("\nquant engine vs oracle on {}: max |diff| = {diff:e}", tg.name);
    assert!(diff < 1e-4, "engine diverged from oracle");
    Ok(())
}
