//! Bench for the Fig.-6 path: timeline construction + utilization
//! sweep-line + per-layer aggregation + ASCII rendering for a balanced
//! split of each model (the exact work behind `odimo fig6`).

use odimo::hw::soc::{simulate, ChannelSplit, SocConfig};
use odimo::hw::Platform;
use odimo::model::{build, ALL_MODELS};
use odimo::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig6");
    let p = Platform::diana();
    for name in ALL_MODELS {
        let g = build(name).unwrap();
        let split: ChannelSplit = g
            .mappable()
            .iter()
            .map(|n| (n.name.clone(), vec![n.cout / 2, n.cout - n.cout / 2]))
            .collect();
        b.run(&format!("timeline_util_{name}"), || {
            let r = simulate(&g, &split, &p, SocConfig::default());
            black_box(r.timeline.utilization());
            black_box(r.timeline.per_layer());
            black_box(r.timeline.render_ascii(72));
        });
    }
    b.finish();
}
