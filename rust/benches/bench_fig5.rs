//! Bench for the Fig.-5 path: abstract-hardware cost evaluation over
//! mappings (the pure-model scoring that replaces the DIANA simulator
//! in the Fig.-5 sweeps).

use odimo::hw::soc::{split_all_digital};
use odimo::hw::AbstractHw;
use odimo::model::{build, ALL_MODELS};
use odimo::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig5");
    for name in ALL_MODELS {
        let g = build(name).unwrap();
        let split = split_all_digital(&g);
        let hw0 = AbstractHw::no_shutdown();
        let hw1 = AbstractHw::ideal_shutdown();
        b.run(&format!("abstract_cost_{name}"), || {
            black_box(hw0.cost(&g, &split));
            black_box(hw1.cost(&g, &split));
        });
    }
    b.finish();
}
