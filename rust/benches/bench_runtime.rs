//! Bench: PJRT runtime hot path — per-step marshalling + execution of
//! the AOT train/eval graphs (tinycnn artifacts). This is the L3 cost
//! that wraps every optimizer step; EXPERIMENTS.md §Perf tracks the
//! breakdown (data generation / literal upload / execute / download).

use std::path::PathBuf;

use anyhow::anyhow;
use odimo::data::DataSource;
use odimo::runtime::{
    assemble_inputs, literal_f32, literal_i32, literal_scalar, ArtifactMeta, ParamState,
    Runtime,
};
use odimo::util::bench::{black_box, Bench};

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tinycnn_meta.json").exists() {
        println!("bench_runtime: artifacts missing, run `make artifacts`");
        return;
    }
    let meta = ArtifactMeta::load(&dir, "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let g = &meta.model;
    let ds = DataSource::train(g, 1);
    let mut b = Bench::new("runtime");

    // batch generation (pure rust, synth.rs)
    b.run("gen_batch_tinycnn", || {
        black_box(ds.batch(0, g.train_batch));
    });

    // literal upload of one batch
    let batch = ds.batch(0, g.train_batch);
    b.run("literal_upload_batch", || {
        black_box(literal_f32(&batch.x, &[batch.n, batch.c, batch.h, batch.w]).unwrap());
    });

    // full state upload (params + momentum)
    let values = meta.load_init_values().unwrap();
    b.run("param_state_upload", || {
        black_box(ParamState::from_host(&meta, values.clone()).unwrap());
    });

    // eval step end-to-end
    let exe = rt.load(meta.graph("eval_deploy").unwrap()).unwrap();
    let params = ParamState::from_init(&meta).unwrap();
    let mapping = odimo::coordinator::Mapping::uniform(g, odimo::model::DIG);
    let assigns: std::collections::BTreeMap<String, odimo::xla::Literal> = meta
        .mappable
        .iter()
        .map(|name| {
            let n = g.node(name).unwrap();
            (name.clone(), literal_f32(&mapping.onehot(name, 2), &[2, n.cout]).unwrap())
        })
        .collect();
    let eb = ds.batch(0, g.eval_batch);
    let xe = literal_f32(&eb.x, &[eb.n, eb.c, eb.h, eb.w]).unwrap();
    let ye = literal_i32(&eb.y, &[eb.n]).unwrap();
    b.run("eval_deploy_step", || {
        let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
            "x" => Ok(&xe),
            "y" => Ok(&ye),
            n if n.starts_with("param:") => params.leaf(&n[6..]),
            n if n.starts_with("assign:") => {
                assigns.get(&n[7..]).ok_or_else(|| anyhow!("missing {n}"))
            }
            n => Err(anyhow!("unexpected {n}")),
        })
        .unwrap();
        black_box(exe.run_to_host(&inputs).unwrap());
    });

    // full train step end-to-end (the per-step cost of every phase)
    let texe = rt.load(meta.graph("train_search_en").unwrap()).unwrap();
    let mut params2 = ParamState::from_init(&meta).unwrap();
    let mut mom = ParamState::zeros(&meta).unwrap();
    let xb = literal_f32(&batch.x, &[batch.n, batch.c, batch.h, batch.w]).unwrap();
    let yb = literal_i32(&batch.y, &[batch.n]).unwrap();
    let scal = literal_scalar(0.01);
    b.run("train_search_en_step", || {
        let inputs = assemble_inputs(&texe.meta, |tm| match tm.name.as_str() {
            "x" => Ok(&xb),
            "y" => Ok(&yb),
            "lr" | "lr_alpha" | "mu" | "wd" | "lam" | "tau" => Ok(&scal),
            n if n.starts_with("param:") => params2.leaf(&n[6..]),
            n if n.starts_with("mom:") => mom.leaf(&n[4..]),
            n => Err(anyhow!("unexpected {n}")),
        })
        .unwrap();
        let mut out = texe.run(&inputs).unwrap();
        params2.replace_from_outputs(&mut out);
        mom.replace_from_outputs(&mut out);
        black_box(&out);
    });
    b.finish();
}
