//! Bench: SoC simulator throughput (the L3 inner loop behind every
//! experiment driver). One full end-to-end inference costing per model
//! on the DIANA platform, a 3-accelerator run on the example platform,
//! plus the min-cost baseline construction (exhaustive per-layer split
//! enumeration). Writes `BENCH_simulator.json` at the repo root (same
//! shape as BENCH_infer.json) so the perf trajectory covers the
//! simulator: `make bench-sim`.

use std::fmt::Write as _;

use odimo::coordinator::baselines;
use odimo::hw::soc::{simulate, split_all_digital, SocConfig};
use odimo::hw::Platform;
use odimo::model::{build, ALL_MODELS};
use odimo::util::bench::{black_box, Bench, Stats};

fn runs_per_s(s: &Stats) -> f64 {
    1e9 / s.median_ns
}

fn main() {
    let mut b = Bench::new("simulator");
    let diana = Platform::diana();
    let tri = Platform::diana_ne16();
    let mut json = String::from("{\n");
    let mut first = true;

    for name in ALL_MODELS {
        let g = build(name).unwrap();
        let split = split_all_digital(&g);
        let s2 = b.run(&format!("simulate_{name}"), || {
            black_box(simulate(&g, &split, &diana, SocConfig::default()));
        });
        // 3-accelerator example platform: even thirds per layer
        let split3 = baselines::even_split(&g, 3).channel_split(3);
        let s3 = b.run(&format!("simulate3_{name}"), || {
            black_box(simulate(&g, &split3, &tri, SocConfig::default()));
        });
        if !first {
            json.push_str(",\n");
        }
        first = false;
        let _ = write!(
            json,
            "  \"{name}\": {{\n    \"sim_median_ns\": {:.0},\n    \"sim_runs_per_s\": {:.1},\n    \"sim3_median_ns\": {:.0},\n    \"sim3_runs_per_s\": {:.1}\n  }}",
            s2.median_ns,
            runs_per_s(&s2),
            s3.median_ns,
            runs_per_s(&s3)
        );
    }

    let g = build("resnet20").unwrap();
    let mc_lat = b.run("min_cost_lat_resnet20", || {
        black_box(baselines::min_cost(&g, &diana, baselines::CostObjective::Latency));
    });
    let mc_en = b.run("min_cost_en_resnet20", || {
        black_box(baselines::min_cost(&g, &diana, baselines::CostObjective::Energy));
    });
    let mc3 = b.run("min_cost_lat3_resnet20", || {
        black_box(baselines::min_cost(&g, &tri, baselines::CostObjective::Latency));
    });
    // 4-unit MPSoC: only tractable on the water-filling fast path (the
    // enumerator-vs-fast-path comparison lives in bench_mincost)
    let quad = Platform::mpsoc4();
    let mc4 = b.run("min_cost_lat4_resnet20", || {
        black_box(baselines::min_cost(&g, &quad, baselines::CostObjective::Latency));
    });
    let _ = write!(
        json,
        ",\n  \"min_cost\": {{\n    \"lat_resnet20_ns\": {:.0},\n    \"en_resnet20_ns\": {:.0},\n    \"lat3_resnet20_ns\": {:.0},\n    \"lat4_resnet20_ns\": {:.0}\n  }}\n}}\n",
        mc_lat.median_ns, mc_en.median_ns, mc3.median_ns, mc4.median_ns
    );

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_simulator.json");
    if let Err(e) = std::fs::write(path, &json) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
    b.finish();
}
