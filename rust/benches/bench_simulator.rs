//! Bench: SoC simulator throughput (the L3 inner loop behind every
//! experiment driver), measured through the `odimo::api::Session`
//! facade the workflows actually use. One full end-to-end inference
//! costing per model on the DIANA platform, a 3-accelerator run on the
//! example platform, plus min-cost baseline construction on the
//! facade (water-filling fast path; the enumerator-vs-fast-path gap
//! lives in bench_mincost). Writes `BENCH_simulator.json` at the repo
//! root (same shape as BENCH_infer.json) so the perf trajectory covers
//! the simulator: `make bench-sim`.
//!
//! Trajectory note (facade migration): `sim*` timings now include the
//! facade's per-call mapping validation + channel-split construction —
//! the real per-call cost of the serving path — where the pre-facade
//! bench timed the bare kernel over a precomputed split. Compare
//! numbers across that boundary accordingly.

use std::fmt::Write as _;

use odimo::api::{CostObjective, MappingSpec, Session, SessionBuilder};
use odimo::model::ALL_MODELS;
use odimo::util::bench::{black_box, Bench, Stats};

fn runs_per_s(s: &Stats) -> f64 {
    1e9 / s.median_ns
}

fn session(model: &str, platform: &str) -> Session {
    SessionBuilder::new(model)
        .platform(platform)
        .threads(1)
        .build()
        .expect("session")
}

fn main() {
    let mut b = Bench::new("simulator");
    let mut json = String::from("{\n");
    let mut first = true;

    for name in ALL_MODELS {
        let s2 = session(name, "diana");
        let all_dig = s2.mapping(&MappingSpec::Baseline("all_8bit".into())).unwrap();
        let t2 = b.run(&format!("simulate_{name}"), || {
            black_box(s2.simulate(&all_dig).unwrap());
        });
        // 3-accelerator example platform: even thirds per layer
        let s3 = session(name, "diana_ne16");
        let thirds = s3.mapping(&MappingSpec::Baseline("even_split".into())).unwrap();
        let t3 = b.run(&format!("simulate3_{name}"), || {
            black_box(s3.simulate(&thirds).unwrap());
        });
        if !first {
            json.push_str(",\n");
        }
        first = false;
        let _ = write!(
            json,
            "  \"{name}\": {{\n    \"sim_median_ns\": {:.0},\n    \"sim_runs_per_s\": {:.1},\n    \"sim3_median_ns\": {:.0},\n    \"sim3_runs_per_s\": {:.1}\n  }}",
            t2.median_ns,
            runs_per_s(&t2),
            t3.median_ns,
            runs_per_s(&t3)
        );
    }

    let diana = session("resnet20", "diana");
    let tri = session("resnet20", "diana_ne16");
    let mc_lat = b.run("min_cost_lat_resnet20", || {
        black_box(diana.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap());
    });
    let mc_en = b.run("min_cost_en_resnet20", || {
        black_box(diana.mapping(&MappingSpec::MinCost(CostObjective::Energy)).unwrap());
    });
    let mc3 = b.run("min_cost_lat3_resnet20", || {
        black_box(tri.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap());
    });
    // 4-unit MPSoC: only tractable on the water-filling fast path (the
    // enumerator-vs-fast-path comparison lives in bench_mincost)
    let quad = session("resnet20", "mpsoc4");
    let mc4 = b.run("min_cost_lat4_resnet20", || {
        black_box(quad.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap());
    });
    let _ = write!(
        json,
        ",\n  \"min_cost\": {{\n    \"lat_resnet20_ns\": {:.0},\n    \"en_resnet20_ns\": {:.0},\n    \"lat3_resnet20_ns\": {:.0},\n    \"lat4_resnet20_ns\": {:.0}\n  }}\n}}\n",
        mc_lat.median_ns, mc_en.median_ns, mc3.median_ns, mc4.median_ns
    );

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_simulator.json");
    if let Err(e) = std::fs::write(path, &json) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
    b.finish();
}
