//! Bench: DIANA SoC simulator throughput (the L3 inner loop behind
//! every experiment driver). One full end-to-end inference costing per
//! model, plus the min-cost baseline construction (exhaustive per-layer
//! split enumeration).

use odimo::coordinator::baselines;
use odimo::hw::soc::{simulate, split_all_digital, SocConfig};
use odimo::model::{build, ALL_MODELS};
use odimo::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("simulator");
    for name in ALL_MODELS {
        let g = build(name).unwrap();
        let split = split_all_digital(&g);
        b.run(&format!("simulate_{name}"), || {
            black_box(simulate(&g, &split, SocConfig::default()));
        });
    }
    let g = build("resnet20").unwrap();
    b.run("min_cost_lat_resnet20", || {
        black_box(baselines::min_cost(&g, baselines::CostObjective::Latency));
    });
    b.run("min_cost_en_resnet20", || {
        black_box(baselines::min_cost(&g, baselines::CostObjective::Energy));
    });
    b.finish();
}
