//! Bench: min-cost mapping construction — the exhaustive composition
//! enumerator (`min_cost_enum`, the historical algorithm and parity
//! oracle) against the water-filling / Pareto-DP fast path, driven the
//! way workflows now reach it: `Session::mapping(MappingSpec::MinCost)`
//! at N = 2..4 accelerators on the ResNet20 layer stack. Guards the
//! fast path against silently regressing to exponential enumeration:
//! CI runs this with `--smoke` (1 repetition) and `make bench-mincost`
//! produces real timings. Writes `BENCH_mincost.json` at the repo root
//! (same shape as the other BENCH_*.json files) and appends to
//! `results/bench_mincost.csv`.

use std::fmt::Write as _;

use odimo::api::{CostObjective, MappingSpec, SessionBuilder};
use odimo::coordinator::baselines;
use odimo::model::build;
use odimo::util::bench::{black_box, Bench};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = Bench::new("mincost");
    if smoke {
        b = b.smoke();
    }
    let g = build("resnet20").unwrap();
    let mut json = String::from("{\n");
    let mut first = true;
    for plat in ["diana", "diana_ne16", "mpsoc4"] {
        let session = SessionBuilder::new("resnet20")
            .platform(plat)
            .threads(1)
            .build()
            .expect("session");
        let p = session.platform();
        let n = p.n_acc();
        // correctness guard: on exact-enumeration platforms the fast
        // path must reproduce the enumerator's mapping bit-for-bit
        if n <= 3 {
            assert_eq!(
                session.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap(),
                baselines::min_cost_enum(&g, p, CostObjective::Latency),
                "fast path diverged from the enumerator on {}",
                p.name
            );
        }
        let enum_lat = b.run(&format!("enum_lat_{}_n{n}", p.name), || {
            black_box(baselines::min_cost_enum(&g, p, CostObjective::Latency));
        });
        let fast_lat = b.run(&format!("fast_lat_{}_n{n}", p.name), || {
            black_box(
                session.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap(),
            );
        });
        let enum_en = b.run(&format!("enum_en_{}_n{n}", p.name), || {
            black_box(baselines::min_cost_enum(&g, p, CostObjective::Energy));
        });
        let fast_en = b.run(&format!("fast_en_{}_n{n}", p.name), || {
            black_box(
                session.mapping(&MappingSpec::MinCost(CostObjective::Energy)).unwrap(),
            );
        });
        if !first {
            json.push_str(",\n");
        }
        first = false;
        let _ = write!(
            json,
            "  \"{}_n{n}\": {{\n    \"enum_lat_ns\": {:.0},\n    \"fast_lat_ns\": {:.0},\n    \"speedup_lat\": {:.2},\n    \"enum_en_ns\": {:.0},\n    \"fast_en_ns\": {:.0},\n    \"speedup_en\": {:.2}\n  }}",
            p.name,
            enum_lat.median_ns,
            fast_lat.median_ns,
            enum_lat.median_ns / fast_lat.median_ns.max(1.0),
            enum_en.median_ns,
            fast_en.median_ns,
            enum_en.median_ns / fast_en.median_ns.max(1.0)
        );
    }
    json.push_str("\n}\n");

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_mincost.json");
    if let Err(e) = std::fs::write(path, &json) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
    b.finish();
}
