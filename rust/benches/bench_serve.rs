//! Bench: the closed-loop serve driver through `odimo::api::Session` —
//! engine throughput (img/s) and simulated p95 queue+compute latency at
//! 1/2/8 worker threads, batched (max_batch 8) vs unbatched
//! (max_batch 1), plus a `faults0` case per thread count: batched
//! serving with an *empty* fault plan attached, which must cost the
//! same as plain batched serving (the zero-fault overhead gate —
//! `tools/check_bench_overhead.py` compares the two loop times). An
//! `obs` case per thread count runs the batched load with the Basic
//! event recorder enabled; the same gate holds it within 2% of
//! `batched` (ARCHITECTURE.md §Observability). One
//! session per thread count owns the frontier and the LRU plan cache,
//! so the timed loop measures steady-state serving (plans compile once,
//! on the first instrumented run). CI smoke-runs this with `--smoke`
//! (tiny request stream, 1 repetition); `make bench-serve` produces
//! real timings. Every case also reports the per-request latency split
//! (mean queue wait vs mean engine compute, simulated ms) so batching
//! pressure stays visible next to throughput. Cluster cases replay one
//! dense trace at `--replicas 1` vs `4` (continuous vs flush batching);
//! their deterministic virtual img/s feed the replica-scaling gate in
//! `tools/check_bench_overhead.py` (r4 must reach >= 2.5x r1).
//! Multi-model cases replay the same dense trace through
//! `Session::serve_multi`: `multi_m1` with a one-model set (the gate
//! holds its loop time within 5% of `cluster_r1` — pure dispatch
//! overhead) and `multi_m2` with the imported custom graph mixed in.
//! Writes `BENCH_serve.json` at the repo root and appends to
//! `results/bench_serve.csv`.

use std::fmt::Write as _;

use odimo::api::{ClusterOpts, FaultPlan, ServeOpts, SessionBuilder};
use odimo::obs::ObsLevel;
use odimo::util::bench::{black_box, Bench};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = Bench::new("serve").slow();
    if smoke {
        b = b.smoke();
    }
    // a private results dir so bench runs never disturb real sweeps;
    // the frontier cache persists across cases (first session sweeps,
    // the rest load it back — exactly the serving-path behavior)
    let dir = std::env::temp_dir().join("odimo_bench_serve");
    let _ = std::fs::remove_dir_all(&dir);
    let mut json = String::from("{\n");
    let mut first = true;
    for threads in [1usize, 2, 8] {
        let mut session = SessionBuilder::new("tinycnn")
            .platform("diana")
            .results_dir(&dir)
            .threads(threads)
            .seed(42)
            .sweep_calib(8)
            .sweep_blend_steps(2)
            .plan_cache_cap(8)
            .build()
            .expect("session");
        let cases = [
            ("batched", 8usize, None),
            ("unbatched", 1, None),
            // fault machinery attached but inert: its cost at zero
            // faults is the overhead the gate keeps below 5%
            ("faults0", 8, Some(FaultPlan::empty())),
        ];
        for (mode, max_batch, fault_plan) in cases {
            let opts = ServeOpts {
                n_requests: Some(if smoke { 16 } else { 128 }),
                max_batch,
                max_wait: 50_000,
                mean_gap: 15_000,
                launch_cycles: 10_000,
                fault_plan,
                ..ServeOpts::default()
            };
            // metrics come from one instrumented run; the timed loop
            // measures the whole closed loop (dispatch + batch + engine)
            // with the session's caches warm
            let rep = session.serve(&opts).expect("serve run");
            let s = b.run(&format!("{mode}_t{threads}"), || {
                black_box(session.serve(&opts).expect("serve run"));
            });
            println!(
                "{mode} x{threads} threads: {:8.1} img/s | p95 {:.3} ms (simulated) | \
                 queue {:.3} / compute {:.3} ms | loop {:.2} ms",
                rep.throughput_img_s,
                rep.p95_ms,
                rep.mean_queue_ms,
                rep.mean_compute_ms,
                s.median_ns / 1e6
            );
            if !first {
                json.push_str(",\n");
            }
            first = false;
            let _ = write!(
                json,
                "  \"{mode}_t{threads}\": {{\n    \"img_s\": {:.1},\n    \
                 \"p95_ms\": {:.4},\n    \"sla_hit_rate\": {:.4},\n    \
                 \"batches\": {},\n    \"queue_ms\": {:.4},\n    \
                 \"compute_ms\": {:.4},\n    \"loop_ms\": {:.2}\n  }}",
                rep.throughput_img_s,
                rep.p95_ms,
                rep.sla_hit_rate,
                rep.total_batches,
                rep.mean_queue_ms,
                rep.mean_compute_ms,
                s.median_ns / 1e6
            );
        }
        // the obs gate: a session with the Basic recorder *enabled* on
        // the identical batched load. `check_bench_overhead.py` holds
        // this within 2% of `batched_tN`, which bounds the disabled
        // recorder (one branch per call site) a fortiori.
        let mut obs_session = SessionBuilder::new("tinycnn")
            .platform("diana")
            .results_dir(&dir)
            .threads(threads)
            .seed(42)
            .sweep_calib(8)
            .sweep_blend_steps(2)
            .plan_cache_cap(8)
            .observer(ObsLevel::Basic)
            .build()
            .expect("session");
        let opts = ServeOpts {
            n_requests: Some(if smoke { 16 } else { 128 }),
            max_batch: 8,
            max_wait: 50_000,
            mean_gap: 15_000,
            launch_cycles: 10_000,
            ..ServeOpts::default()
        };
        let rep = obs_session.serve(&opts).expect("serve run");
        let s = b.run(&format!("obs_t{threads}"), || {
            black_box(obs_session.serve(&opts).expect("serve run"));
        });
        println!(
            "obs x{threads} threads: {:8.1} img/s | p95 {:.3} ms (simulated) | \
             {} events | loop {:.2} ms",
            rep.throughput_img_s,
            rep.p95_ms,
            obs_session.recorder().len(),
            s.median_ns / 1e6
        );
        let _ = write!(
            json,
            ",\n  \"obs_t{threads}\": {{\n    \"img_s\": {:.1},\n    \
             \"p95_ms\": {:.4},\n    \"sla_hit_rate\": {:.4},\n    \
             \"batches\": {},\n    \"events\": {},\n    \"loop_ms\": {:.2}\n  }}",
            rep.throughput_img_s,
            rep.p95_ms,
            rep.sla_hit_rate,
            rep.total_batches,
            obs_session.recorder().len(),
            s.median_ns / 1e6
        );
    }
    // cluster cases: one dense synthesized trace (mean gap far below
    // the service time, so a single replica saturates) replayed at
    // r=1 and r=4, continuous batching vs flush-only. The replica
    // scaling gate compares the *virtual* throughput figures — they
    // are deterministic, so the gate holds even on smoke runs.
    let mut session = SessionBuilder::new("tinycnn")
        .platform("diana")
        .results_dir(&dir)
        .threads(2)
        .seed(42)
        .sweep_calib(8)
        .sweep_blend_steps(2)
        .plan_cache_cap(8)
        .build()
        .expect("session");
    let dense = ServeOpts {
        n_requests: Some(if smoke { 32 } else { 96 }),
        max_batch: 8,
        max_wait: 50_000,
        mean_gap: 2_000,
        launch_cycles: 10_000,
        ..ServeOpts::default()
    };
    let trace = session.synth_trace(&dense).expect("trace");
    let cluster_cases = [
        ("cluster_r1", 1usize, true),
        ("cluster_r4", 4, true),
        ("cluster_r4_flush", 4, false),
    ];
    for (name, replicas, continuous) in cluster_cases {
        let copts = ClusterOpts {
            replicas,
            serve: dense.clone(),
            continuous,
            steal_max: 2,
            compile_cycles: 5_000,
            plan_cache_cap: 8,
        };
        let rep = session.serve_cluster(&copts, Some(&trace)).expect("cluster run");
        let s = b.run(name, || {
            black_box(session.serve_cluster(&copts, Some(&trace)).expect("cluster run"));
        });
        println!(
            "{name}: {:8.1} virtual img/s | makespan {:.3} ms | {} steal(s) | loop {:.2} ms",
            rep.virtual_img_s,
            rep.makespan_ms,
            rep.steals,
            s.median_ns / 1e6
        );
        let _ = write!(
            json,
            ",\n  \"{name}\": {{\n    \"virtual_img_s\": {:.4},\n    \
             \"makespan_ms\": {:.4},\n    \"steals\": {},\n    \
             \"loop_ms\": {:.2}\n  }}",
            rep.virtual_img_s,
            rep.makespan_ms,
            rep.steals,
            s.median_ns / 1e6
        );
    }
    // multi-model cases: `multi_m1` replays the identical dense trace
    // through the multi-model dispatch plane with a one-model set —
    // the overhead gate holds its loop time within 5% of `cluster_r1`
    // (same trace, same options, so the delta is pure dispatch cost).
    // `multi_m2` adds the imported custom graph and a mixed trace, the
    // two-model figure the gate requires to stay live.
    let custom = concat!(env!("CARGO_MANIFEST_DIR"), "/../config/graph_custom.json");
    let multi_cases = [
        ("multi_m1", vec!["tinycnn".to_string()]),
        ("multi_m2", vec!["tinycnn".to_string(), custom.to_string()]),
    ];
    for (name, specs) in multi_cases {
        let copts = ClusterOpts {
            replicas: 1,
            serve: dense.clone(),
            continuous: true,
            steal_max: 2,
            compile_cycles: 5_000,
            plan_cache_cap: 8,
        };
        let mtrace = if specs.len() == 1 {
            trace.clone()
        } else {
            session.synth_trace_multi(&specs, &dense).expect("mixed trace")
        };
        let rep = session.serve_multi(&specs, &copts, Some(&mtrace)).expect("multi run");
        let s = b.run(name, || {
            black_box(session.serve_multi(&specs, &copts, Some(&mtrace)).expect("multi run"));
        });
        println!(
            "{name} ({}): {:8.1} virtual img/s | makespan {:.3} ms | loop {:.2} ms",
            rep.model,
            rep.virtual_img_s,
            rep.makespan_ms,
            s.median_ns / 1e6
        );
        let _ = write!(
            json,
            ",\n  \"{name}\": {{\n    \"virtual_img_s\": {:.4},\n    \
             \"makespan_ms\": {:.4},\n    \"models\": {},\n    \
             \"loop_ms\": {:.2}\n  }}",
            rep.virtual_img_s,
            rep.makespan_ms,
            specs.len(),
            s.median_ns / 1e6
        );
    }
    json.push_str("\n}\n");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    if let Err(e) = std::fs::write(path, &json) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
    b.finish();
}
