//! Bench for the Table-I path: full deployment scoring (simulate +
//! fragmentation accounting + utilization) of every baseline mapping on
//! every benchmark model — the exact per-row work of `odimo table1`.

use odimo::coordinator::baselines::{self, BASELINE_NAMES};
use odimo::coordinator::scheduler::deploy;
use odimo::hw::soc::SocConfig;
use odimo::hw::Platform;
use odimo::model::{build, ALL_MODELS};
use odimo::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("table1");
    let p = Platform::diana();
    for name in ALL_MODELS {
        let g = build(name).unwrap();
        let mappings: Vec<_> = BASELINE_NAMES
            .iter()
            .map(|bn| baselines::by_name(&g, &p, bn).unwrap())
            .collect();
        b.run(&format!("deploy_all_baselines_{name}"), || {
            for m in &mappings {
                black_box(deploy(&g, m, &p, SocConfig::default()));
            }
        });
    }
    b.finish();
}
