//! Bench for the Table-I path: full deployment scoring (simulate +
//! fragmentation accounting + utilization) of every baseline mapping on
//! every benchmark model — the exact per-row work of `odimo table1`.

use odimo::api::{MappingSpec, SessionBuilder};
use odimo::coordinator::baselines::BASELINE_NAMES;
use odimo::model::ALL_MODELS;
use odimo::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("table1");
    for name in ALL_MODELS {
        let session = SessionBuilder::new(name)
            .platform("diana")
            .threads(1)
            .build()
            .expect("session");
        let mappings: Vec<_> = BASELINE_NAMES
            .iter()
            .map(|bn| {
                session
                    .mapping(&MappingSpec::Baseline((*bn).to_string()))
                    .unwrap()
            })
            .collect();
        b.run(&format!("deploy_all_baselines_{name}"), || {
            for m in &mappings {
                black_box(session.deploy(m).unwrap());
            }
        });
    }
    b.finish();
}
