//! Quantized-inference engine throughput: the planned im2col/GEMM
//! engine driven through `odimo::api::Session::infer` (one session per
//! thread count; plans compile once into the session's cache) vs the
//! naive interpreter oracle (`quant::ref`), plus a per-model kernel
//! head-to-head (scalar reference loops vs the SIMD backend, and the
//! direct-convolution paths vs forced im2col) and serve-side plan-cache
//! hit/miss timings so plan compilation cost stays visible in the perf
//! trajectory. Reports img/s and writes `BENCH_infer.json` at the repo
//! root; `tools/check_bench_infer.py` gates it (SIMD never slower than
//! scalar, scalar unregressed vs the committed baseline).
//!
//!     make bench-infer    # or: cargo bench --bench bench_infer
//!
//! CI smoke-runs this with `--smoke` (1 repetition per case).

use std::fmt::Write as _;

use odimo::api::{Session, SessionBuilder};
use odimo::hw::Platform;
use odimo::model::{resnet20, Graph};
use odimo::quant::r#ref::RefNet;
use odimo::quant::{
    synth_mapping as random_mapping, synth_params, synth_params_on, ConvAlgo, KernelBackend,
    ParamSet, QuantNet, QuantPlan,
};
use odimo::serve::batcher::PlanCache;
use odimo::util::bench::{black_box, Bench};
use odimo::util::prng::Pcg32;

const BATCH: usize = 8;
const SEED: u64 = 11;

fn random_input(g: &Graph, batch: usize, seed: u64) -> Vec<f32> {
    let (c, h, w) = g.input_shape;
    let mut rng = Pcg32::new(seed, 77);
    (0..batch * c * h * w).map(|_| rng.next_f32()).collect()
}

fn imgs_per_s(median_ns: f64) -> f64 {
    BATCH as f64 / (median_ns * 1e-9)
}

fn session(model: &str, threads: usize) -> Session {
    SessionBuilder::new(model)
        .platform("diana")
        .threads(threads)
        .seed(SEED)
        .build()
        .expect("session")
}

fn bench_model(b: &mut Bench, model: &str, json: &mut String) {
    let mut s1 = session(model, 1);
    let g = s1.graph().clone();
    let mapping = random_mapping(&g, 3);
    // the oracle, over the session's own parameter derivation
    let (names, values) = synth_params_on(&g, s1.platform(), SEED);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let oracle = RefNet::compile(&params, &g, &mapping, s1.platform()).unwrap();
    let x = random_input(&g, BATCH, 7);

    // correctness gate: never publish numbers off a diverged engine
    let ye = s1.infer(&mapping, &x, BATCH).unwrap();
    let yr = oracle.forward(&x, BATCH).unwrap();
    let diff = ye
        .iter()
        .zip(&yr)
        .map(|(a, c)| (a - c).abs())
        .fold(0f32, f32::max);
    assert!(diff < 1e-4, "{}: engine diverged from oracle by {diff}", g.name);

    let s_ref = b.run(&format!("{}_naive_b{BATCH}", g.name), || {
        black_box(oracle.forward(&x, BATCH).unwrap());
    });
    let s_eng = b.run(&format!("{}_engine_b{BATCH}", g.name), || {
        black_box(s1.infer(&mapping, &x, BATCH).unwrap());
    });
    let speedup = s_ref.median_ns / s_eng.median_ns;
    println!(
        "{:>10}: naive {:8.1} img/s | engine {:8.1} img/s | {:.2}x single-thread",
        g.name,
        imgs_per_s(s_ref.median_ns),
        imgs_per_s(s_eng.median_ns),
        speedup
    );
    let _ = write!(
        json,
        "  \"{}\": {{\n    \"batch\": {BATCH},\n    \"naive_img_s\": {:.1},\n    \"engine_img_s\": {:.1},\n    \"speedup_1t\": {:.2}",
        g.name,
        imgs_per_s(s_ref.median_ns),
        imgs_per_s(s_eng.median_ns),
        speedup
    );
    for threads in [2usize, 4, 8] {
        let mut st = session(model, threads);
        let s = b.run(&format!("{}_engine_b{BATCH}_t{threads}", g.name), || {
            black_box(st.infer(&mapping, &x, BATCH).unwrap());
        });
        println!(
            "{:>10}: engine x{threads} threads {:8.1} img/s ({:.2}x vs 1t)",
            g.name,
            imgs_per_s(s.median_ns),
            s_eng.median_ns / s.median_ns
        );
        let _ = write!(
            json,
            ",\n    \"engine_img_s_t{threads}\": {:.1}",
            imgs_per_s(s.median_ns)
        );
    }

    // kernel backends head-to-head on the raw engine (no session, no
    // pool): scalar reference loops vs the resolved SIMD backend, plus
    // the same SIMD plan with every conv forced back onto im2col so the
    // direct-convolution win is visible on its own
    let p = s1.platform();
    let scalar_net =
        QuantNet::compile_params_backend(&params, &g, &mapping, p, KernelBackend::Scalar).unwrap();
    let simd_net =
        QuantNet::compile_params_backend(&params, &g, &mapping, p, KernelBackend::Simd).unwrap();
    let im2col_net = QuantNet::compile_params_with(
        &params,
        &g,
        &mapping,
        p,
        KernelBackend::Simd,
        Some(ConvAlgo::Im2col),
    )
    .unwrap();
    assert_eq!(
        simd_net.forward(&x, BATCH).unwrap(),
        scalar_net.forward(&x, BATCH).unwrap(),
        "{}: SIMD backend diverged from scalar",
        g.name
    );
    let s_scalar = b.run(&format!("{}_scalar_b{BATCH}", g.name), || {
        black_box(scalar_net.forward(&x, BATCH).unwrap());
    });
    let s_simd = b.run(&format!("{}_simd_b{BATCH}", g.name), || {
        black_box(simd_net.forward(&x, BATCH).unwrap());
    });
    let s_im2col = b.run(&format!("{}_im2col_b{BATCH}", g.name), || {
        black_box(im2col_net.forward(&x, BATCH).unwrap());
    });
    println!(
        "{:>10}: scalar {:8.1} img/s | simd[{:?}] {:8.1} img/s ({:.2}x) | \
         im2col-only {:8.1} img/s",
        g.name,
        imgs_per_s(s_scalar.median_ns),
        simd_net.isa(),
        imgs_per_s(s_simd.median_ns),
        s_scalar.median_ns / s_simd.median_ns,
        imgs_per_s(s_im2col.median_ns)
    );
    let _ = write!(
        json,
        ",\n    \"scalar_img_s\": {:.1},\n    \"simd_img_s\": {:.1},\n    \
         \"simd_speedup\": {:.2},\n    \"im2col_img_s\": {:.1},\n    \
         \"direct_img_s\": {:.1}",
        imgs_per_s(s_scalar.median_ns),
        imgs_per_s(s_simd.median_ns),
        s_scalar.median_ns / s_simd.median_ns,
        imgs_per_s(s_im2col.median_ns),
        imgs_per_s(s_simd.median_ns)
    );
    let _ = write!(json, "\n  }}");
}

/// Plan-cache handle cost: cold compile (miss) vs cached fetch (hit) —
/// the amortization the session-owned LRU cache buys per batch.
fn bench_plan_cache(b: &mut Bench, json: &mut String) {
    let g = resnet20();
    let p = Platform::diana();
    let (names, values) = synth_params(&g, 19);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = random_mapping(&g, 5);
    let key = QuantPlan::cache_key(&g.name, g.spec_hash(), &p.name, &mapping, KernelBackend::Auto);
    let s_miss = b.run("plan_cache_miss_resnet20", || {
        let mut cold = PlanCache::new(1);
        cold.get_or_compile(key, &mapping, || {
            QuantNet::compile_params(&params, &g, &mapping, &p)
        })
        .unwrap();
        black_box(cold.misses);
    });
    let mut cache = PlanCache::new(2);
    cache
        .get_or_compile(key, &mapping, || QuantNet::compile_params(&params, &g, &mapping, &p))
        .unwrap();
    let s_hit = b.run("plan_cache_hit_resnet20", || {
        cache
            .get_or_compile(key, &mapping, || {
                QuantNet::compile_params(&params, &g, &mapping, &p)
            })
            .unwrap();
        black_box(cache.hits);
    });
    println!(
        "plan cache: miss (compile) {:.3} ms | hit {:.0} ns | {:.0}x",
        s_miss.median_ns / 1e6,
        s_hit.median_ns,
        s_miss.median_ns / s_hit.median_ns.max(1.0)
    );
    let _ = write!(
        json,
        "  \"plan_cache\": {{\n    \"miss_compile_ns\": {:.0},\n    \"hit_ns\": {:.0},\n    \
         \"speedup\": {:.0}\n  }}",
        s_miss.median_ns,
        s_hit.median_ns,
        s_miss.median_ns / s_hit.median_ns.max(1.0)
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut b = Bench::new("infer").slow();
    if smoke {
        b = b.smoke();
    }
    let mut json = String::from("{\n");
    bench_model(&mut b, "tinycnn", &mut json);
    json.push_str(",\n");
    bench_model(&mut b, "resnet20", &mut json);
    json.push_str(",\n");
    bench_plan_cache(&mut b, &mut json);
    json.push_str("\n}\n");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_infer.json");
    if let Err(e) = std::fs::write(path, &json) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
    b.finish();
}
