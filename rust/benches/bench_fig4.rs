//! Bench for the Fig.-4 regeneration path: the per-lambda pipeline cost
//! is dominated by training steps (measured in bench_runtime); here we
//! measure the surrounding machinery at full fidelity — discretization,
//! deployment costing, Pareto extraction — over a realistic sweep-sized
//! point set, so regressions in the driver itself are visible.

use std::collections::BTreeMap;

use odimo::api::SessionBuilder;
use odimo::coordinator::{discretize::discretize, Mapping, SearchPoint};
use odimo::metrics::{ascii_scatter, pareto_front, points_csv};
use odimo::model::resnet20;
use odimo::util::bench::{black_box, Bench};
use odimo::util::prng::Pcg32;

fn main() {
    let g = resnet20();
    let mut rng = Pcg32::new(42, 1);
    let mut b = Bench::new("fig4");
    let session = SessionBuilder::new("resnet20")
        .platform("diana")
        .threads(1)
        .build()
        .expect("session");

    // discretize from random alpha logits (22 mappable layers)
    let alphas: BTreeMap<String, Vec<f32>> = g
        .mappable()
        .iter()
        .map(|n| {
            let v: Vec<f32> = (0..2 * n.cout).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            (n.name.clone(), v)
        })
        .collect();
    b.run("discretize_resnet20", || {
        black_box(discretize(&g, &alphas, 2).unwrap());
    });

    // deployment costing of one mapping, through the facade
    let mapping = discretize(&g, &alphas, 2).unwrap();
    b.run("deploy_cost_resnet20", || {
        black_box(session.deploy(&mapping).unwrap());
    });

    // pareto + reporting over a sweep-sized point set
    let points: Vec<SearchPoint> = (0..24)
        .map(|i| SearchPoint {
            label: if i % 5 == 0 { format!("base{i}") } else { format!("odimo_{i}") },
            lambda: i as f64,
            accuracy: rng.next_f32() as f64,
            latency_ms: rng.next_f32() as f64 * 2.0,
            energy_uj: rng.next_f32() as f64 * 40.0,
            total_cycles: 1000 + i as u64,
            util: vec![0.9, 0.3],
            aimc_channel_frac: 0.5,
            mapping: Mapping::uniform(&g, 0),
        })
        .collect();
    b.run("pareto_and_reports", || {
        black_box(pareto_front(&points, |p| p.energy_uj));
        black_box(points_csv(&points));
        black_box(ascii_scatter(&points, |p| p.energy_uj, 64, 16));
    });
    b.finish();
}
